#!/usr/bin/env python
"""CI bench-regression gate: current results.json vs committed baseline.

    python scripts/check_bench_regression.py \
        experiments/bench-smoke/results.json \
        [--baseline benchmarks/baselines/ci_baseline.json] [--update]

Only **deterministic** rows are gated — step counts, prefill tokens
computed/shared, steady-state pool blocks, concurrency at equal KV
memory, scheduler-tick TTFT. They are exact functions of the engine's
admission/eviction/chunking logic on the fixed bench-smoke scenario
set, so any drift is a real behaviour change: the gate fails CI when a
metric moves in the *worse* direction and prints a loud notice (without
failing) when it moves in the better direction, so an improvement is a
deliberate baseline update, never an invisible ratchet.

Wall-clock rows (ms / us_per_call / ns / %) are runner-dependent noise
on shared CI hardware: they are reported as a trajectory table for the
artifact trail and never gated.

``--update`` rewrites the baseline from the current results (commit the
diff — that IS the ratchet step).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_BASELINE = REPO / "benchmarks" / "baselines" / "ci_baseline.json"

# Units that mark a row as wall-clock (trajectory only, never gated).
WALL_UNITS = {"ms", "us_per_call", "ns", "s", "%"}

# name -> direction the metric is allowed to move:
#   "le": current must be <= baseline (lower is better / bounded)
#   "ge": current must be >= baseline (higher is better)
#   "eq": scenario constant — any drift means the bench itself changed
GATES = {
    "paged_kv.kv_token_capacity": "eq",
    "paged_kv.max_concurrent.fixed_stripe": "eq",
    "paged_kv.max_concurrent.paged": "ge",
    "paged_kv.concurrency_ratio": "ge",
    "paged_kv.steps_to_drain.fixed_stripe": "eq",
    "paged_kv.steps_to_drain.paged": "le",
    "paged_kv.pool_occupancy_after_drain": "eq",
    "paged_kv.shared_prefix.requests": "eq",
    "paged_kv.shared_prefix.prefill_tokens.unshared": "eq",
    "paged_kv.shared_prefix.prefill_tokens.shared": "le",
    "paged_kv.shared_prefix.steady_state_blocks.unshared": "eq",
    "paged_kv.shared_prefix.steady_state_blocks.shared": "le",
    "paged_kv.shared_prefix.tokens_reused": "ge",
    "paged_kv.shared_prefix.prefill_reduction": "ge",
    "serving.chunked.monolithic.max_event_prefill_tokens": "eq",
    "serving.chunked.chunked.max_event_prefill_tokens": "le",
    "serving.chunked.monolithic.events": "eq",
    "serving.chunked.chunked.events": "le",
    "serving.open_loop.ttft_p50": "le",
    "serving.open_loop.ttft_p99": "le",
    "serving.open_loop.ticks": "le",
}


def _rows(doc: dict) -> dict:
    out = {}
    for r in doc.get("rows", []):
        try:
            out[r["name"]] = (float(r["value"]), r.get("unit", ""))
        except (TypeError, ValueError):
            continue            # non-numeric rows carry no gate
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", type=Path,
                    help="results.json from the current bench run")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current results")
    args = ap.parse_args()

    cur_doc = json.loads(args.results.read_text())
    if args.update:
        args.baseline.parent.mkdir(parents=True, exist_ok=True)
        args.baseline.write_text(json.dumps(cur_doc, indent=2,
                                            default=str) + "\n")
        print(f"baseline updated from {args.results} -> {args.baseline}")
        return 0

    base_doc = json.loads(args.baseline.read_text())
    cur, base = _rows(cur_doc), _rows(base_doc)

    failures, improvements, gated = [], [], 0
    for name, direction in GATES.items():
        if name not in base:
            continue            # baseline predates this metric: un-gated
        if name not in cur:
            failures.append(f"{name}: gated metric missing from current "
                            f"run (baseline {base[name][0]:g})")
            continue
        c, b = cur[name][0], base[name][0]
        gated += 1
        worse = (direction == "eq" and c != b) \
            or (direction == "le" and c > b) \
            or (direction == "ge" and c < b)
        better = not worse and c != b
        tag = f"{name}: current {c:g} vs baseline {b:g} [{direction}]"
        if worse:
            failures.append(tag)
        elif better:
            improvements.append(tag)

    base_sha = str(base_doc.get("meta", {}).get("git_sha", "?"))[:10]
    print(f"gated {gated} deterministic metrics against "
          f"{args.baseline.name} (baseline sha {base_sha})")

    # wall-clock trajectory: informational only
    wall = [(n, cur[n][0], base[n][0]) for n in sorted(cur)
            if n in base and cur[n][1] in WALL_UNITS]
    if wall:
        print("\nwall-clock trajectory (informational, not gated):")
        for n, c, b in wall:
            delta = (c / b - 1) * 100 if b else float("inf")
            print(f"  {n}: {c:g} (baseline {b:g}, {delta:+.1f}%)")

    if improvements:
        print("\nimproved beyond baseline — consider ratcheting with "
              "--update and committing the diff:")
        for line in improvements:
            print(f"  {line}")
    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("\nno deterministic regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
