#!/usr/bin/env python
"""ULP audit for the paged-attention kernels (decode + fused window).

    PYTHONPATH=src python scripts/ulp_audit.py [--out DIR] [--seeds N]

Runs the float32 differential grids — the same shape families the
pytest suite gates — in interpret mode and records the *measured*
maximum ULP distance between the Pallas kernel and the streaming jnp
oracle, per configuration, for both the attention output and the LSE.
The summary (JSON + markdown) is uploaded as a CI artifact so the
contract headroom is visible over time: the tests assert out <= 4 ulp
/ lse <= 32 ulp; this audit shows how close the toolchain actually
sits to those bounds (historically out is bitwise on nearly every
case and lse within ~16 ulp — see kernels/paged_attention/ref.py for
why universal bitwise equality is not contractable).

Exit code 1 if any case exceeds the contract — the audit is a gate,
not just a report.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

OUT_ULP, LSE_ULP = 4, 32

# B, Hq, Hkv, hd, bs, max_blocks, sliding_window  (float32 only: ULP
# distance against an f32 oracle is meaningless for bf16 outputs)
DECODE_GRID = [
    (1, 4, 1, 64, 16, 4, 0),
    (2, 8, 2, 64, 16, 4, 0),
    (3, 4, 4, 32, 8, 6, 0),
    (4, 2, 1, 128, 16, 5, 0),
    (2, 8, 8, 64, 8, 4, 0),
    (4, 4, 1, 64, 16, 5, 24),
]

# S, B, Hq, Hkv, hd, bs, max_blocks, sliding_window
WINDOW_GRID = [
    (1, 2, 8, 2, 64, 16, 4, 0),
    (2, 3, 4, 4, 32, 8, 6, 0),
    (4, 2, 8, 2, 64, 16, 4, 0),
    (4, 3, 4, 1, 64, 8, 6, 0),
    (8, 2, 4, 2, 64, 16, 4, 0),
    (8, 2, 4, 4, 32, 8, 8, 0),
    (4, 2, 8, 2, 64, 16, 5, 24),
]


def _ulp_key(x: np.ndarray) -> np.ndarray:
    """Map float32 bit patterns to a monotonic integer line so the ULP
    distance between any two finite floats (sign crossings included) is
    a plain integer difference; -0.0 and +0.0 both land on 0."""
    i = np.ascontiguousarray(np.float32(x)).view(np.int32).astype(np.int64)
    return np.where(i >= 0, i, np.int64(-2147483648) - i)


def ulp_max(a, b) -> int:
    return int(np.max(np.abs(_ulp_key(a) - _ulp_key(b)), initial=0))


def _decode_case(jax, jnp, B, Hq, Hkv, hd, bs, mb, seed):
    nb = B * mb + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
    pk = jax.random.normal(ks[1], (nb, bs, Hkv, hd), jnp.float32)
    pv = jax.random.normal(ks[2], (nb, bs, Hkv, hd), jnp.float32)
    rng = np.random.default_rng(seed + B * 1000 + hd)
    free = list(rng.permutation(np.arange(1, nb)))
    lens = np.zeros(B, np.int32)
    table = np.zeros((B, mb), np.int32)
    for b in range(B):
        lens[b] = int(rng.integers(1, mb * bs + 1))
        for i in range(-(-int(lens[b]) // bs)):
            table[b, i] = free.pop()
    return q, pk, pv, jnp.asarray(table), jnp.asarray(lens)


def _window_case(jax, jnp, B, S, Hq, Hkv, hd, bs, mb, seed):
    nb = B * mb + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.float32)
    pk = jax.random.normal(ks[1], (nb, bs, Hkv, hd), jnp.float32)
    pv = jax.random.normal(ks[2], (nb, bs, Hkv, hd), jnp.float32)
    rng = np.random.default_rng(seed + B * 1000 + S * 100 + hd)
    free = list(rng.permutation(np.arange(1, nb)))
    base = np.zeros(B, np.int32)
    table = np.zeros((B, mb), np.int32)
    for b in range(B):
        base[b] = int(rng.integers(0, mb * bs - S + 1))
        for i in range(-(-int(base[b] + S) // bs)):
            table[b, i] = free.pop()
    return q, pk, pv, jnp.asarray(table), jnp.asarray(base)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=Path, default=Path("experiments/ulp-audit"))
    ap.add_argument("--seeds", type=int, default=2)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.kernels.paged_attention.ops import (
        paged_decode_attention, paged_window_attention)
    from repro.kernels.paged_attention.ref import (
        paged_decode_attention_ref, paged_window_attention_ref)

    cases = []
    for B, Hq, Hkv, hd, bs, mb, win in DECODE_GRID:
        for seed in range(args.seeds):
            q, pk, pv, tb, ln = _decode_case(jax, jnp, B, Hq, Hkv, hd, bs,
                                             mb, seed)
            out, lse = paged_decode_attention(q, pk, pv, tb, ln,
                                              sliding_window=win)
            ro, rl = paged_decode_attention_ref(q, pk, pv, tb, ln,
                                                sliding_window=win)
            cases.append({
                "kind": "decode", "seed": seed, "sliding_window": win,
                "shape": f"B{B} Hq{Hq} Hkv{Hkv} hd{hd} bs{bs} mb{mb}",
                "out_ulp": ulp_max(out, ro), "lse_ulp": ulp_max(lse, rl)})
    for S, B, Hq, Hkv, hd, bs, mb, win in WINDOW_GRID:
        for seed in range(args.seeds):
            q, pk, pv, tb, base = _window_case(jax, jnp, B, S, Hq, Hkv, hd,
                                               bs, mb, seed)
            out, lse = paged_window_attention(q, pk, pv, tb, base,
                                              sliding_window=win)
            ro, rl = paged_window_attention_ref(q, pk, pv, tb, base,
                                                sliding_window=win)
            cases.append({
                "kind": "window", "seed": seed, "sliding_window": win,
                "shape": f"S{S} B{B} Hq{Hq} Hkv{Hkv} hd{hd} bs{bs} mb{mb}",
                "out_ulp": ulp_max(out, ro), "lse_ulp": ulp_max(lse, rl)})

    worst_out = max(c["out_ulp"] for c in cases)
    worst_lse = max(c["lse_ulp"] for c in cases)
    ok = worst_out <= OUT_ULP and worst_lse <= LSE_ULP
    summary = {
        "contract": {"out_ulp": OUT_ULP, "lse_ulp": LSE_ULP},
        "worst": {"out_ulp": worst_out, "lse_ulp": worst_lse},
        "n_cases": len(cases), "ok": ok, "cases": cases,
    }
    args.out.mkdir(parents=True, exist_ok=True)
    (args.out / "ulp_audit.json").write_text(
        json.dumps(summary, indent=2) + "\n")
    lines = ["# Paged-attention ULP audit", "",
             f"Contract: out <= {OUT_ULP} ulp, lse <= {LSE_ULP} ulp "
             "(f32, interpret mode vs streaming oracle).", "",
             f"Worst observed: out {worst_out} ulp, lse {worst_lse} ulp "
             f"over {len(cases)} cases.", "",
             "| kind | shape | win | seed | out ulp | lse ulp |",
             "|------|-------|----:|-----:|--------:|--------:|"]
    lines += [f"| {c['kind']} | {c['shape']} | {c['sliding_window']} "
              f"| {c['seed']} | {c['out_ulp']} | {c['lse_ulp']} |"
              for c in cases]
    (args.out / "ulp_audit.md").write_text("\n".join(lines) + "\n")

    print(f"{len(cases)} cases: worst out {worst_out} ulp "
          f"(contract {OUT_ULP}), worst lse {worst_lse} ulp "
          f"(contract {LSE_ULP}) -> {args.out}")
    if not ok:
        print("ULP CONTRACT EXCEEDED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
