"""Quick dev check: every reduced arch runs train/prefill/decode on CPU."""
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import build_model


def batch_for(cfg, B=2, S=32):
    rng = jax.random.PRNGKey(0)
    b = {"tokens": jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        b["tokens"] = b["tokens"][:, : S - cfg.n_patches + 1]
        b["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.frontend == "audio":
        b["frames"] = jax.random.normal(
            rng, (B, cfg.n_frames, cfg.d_model), cfg.dtype)
    return b


def main():
    only = sys.argv[1:] or ARCH_IDS
    for arch in only:
        t0 = time.time()
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(1))
        n = sum(x.size for x in jax.tree.leaves(params))
        batch = batch_for(cfg)
        loss, metrics = jax.jit(m.train_loss)(params, batch)
        assert jnp.isfinite(loss), f"{arch}: train loss not finite"
        pre = dict(batch)
        pre["tokens"] = pre["tokens"][:, :-1]
        logits, cache = jax.jit(m.prefill)(params, pre)
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
        # decode against a fresh capacity-64 cache at position 32
        cache64 = m.init_cache(2, 64)
        tok = jnp.ones((2, 1), jnp.int32)
        lg, cache64 = jax.jit(m.decode_step)(params, tok, cache64,
                                             jnp.int32(32))
        assert lg.shape == (2, 1, cfg.vocab_size), (arch, lg.shape)
        assert jnp.all(jnp.isfinite(lg.astype(jnp.float32)))
        print(f"OK {arch:20s} params={n:>9,d} loss={float(loss):.3f} "
              f"({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
