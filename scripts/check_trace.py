#!/usr/bin/env python
"""Validate a Chrome trace-event JSON produced by ``--trace-out``.

Structural checks a Perfetto-loadable serve trace must pass:

* top level is an object with a non-empty ``traceEvents`` list;
* every event carries ``name``/``ph``/``pid``/``tid`` with a known
  phase (``X`` complete, ``i`` instant, ``C`` counter, ``M``
  metadata), non-metadata events a ``ts``, ``X`` events a non-negative
  ``dur``, and counters a numeric ``args`` dict;
* the process-naming metadata for the serve loop, request, and pool
  tracks is present;
* at least one full request lifecycle span (``request`` on a request
  track) exists, and — when the trace has serve-loop events at all,
  i.e. the run went through ``AsyncServeLoop`` (``--stream``) — at
  least one tick-phase span. A synchronous ``drain()`` trace has no
  loop track and is still valid.

CI's trace-smoke step runs a tiny ``--trace-out`` serve and gates on
this. Importable: ``validate(path)`` returns the error list.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

PHASES = {"X", "i", "C", "M"}
TICK_PHASES = {"apply-cancels", "fill", "dispatch", "plan-window",
               "commit-wait", "emit"}
PID_LOOP, PID_REQUESTS, PID_POOL = 0, 1, 2


def validate(path: str | Path) -> list:
    """Return a list of problems with the trace file; empty = valid."""
    try:
        trace = json.loads(Path(path).read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable trace: {e}"]
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a traceEvents list"]
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["traceEvents is empty — the run recorded nothing"]

    errors = []
    named_pids = set()
    loop_events = 0
    tick_spans = 0
    lifecycle_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        missing = [k for k in ("name", "ph", "pid", "tid")
                   if k not in ev]
        if missing:
            errors.append(f"event {i} ({ev.get('name', '?')}): missing "
                          f"keys {missing}")
            continue
        ph = ev["ph"]
        if ph not in PHASES:
            errors.append(f"event {i} ({ev['name']}): unknown phase "
                          f"{ph!r}")
            continue
        if ph != "M" and "ts" not in ev:
            errors.append(f"event {i} ({ev['name']}): missing ts")
            continue
        if ph == "M" and ev["name"] == "process_name":
            named_pids.add(ev["pid"])
        if ph != "M" and ev["pid"] == PID_LOOP:
            loop_events += 1
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                errors.append(f"event {i} ({ev['name']}): complete span "
                              f"needs a non-negative dur, got "
                              f"{ev.get('dur')!r}")
            if ev["pid"] == PID_LOOP and ev["name"] in TICK_PHASES:
                tick_spans += 1
            if ev["pid"] == PID_REQUESTS and ev["name"] == "request":
                lifecycle_spans += 1
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                errors.append(f"event {i} ({ev['name']}): counter needs "
                              f"a numeric args dict, got {args!r}")

    for pid, track in ((PID_LOOP, "serve-loop"),
                       (PID_REQUESTS, "requests"), (PID_POOL, "kv-pool")):
        if pid not in named_pids:
            errors.append(f"no process_name metadata for the {track} "
                          f"track (pid {pid})")
    if loop_events and not tick_spans:
        errors.append("serve-loop track has events but no tick-phase "
                      f"spans (expected any of {sorted(TICK_PHASES)})")
    if not lifecycle_spans:
        errors.append("no completed request lifecycle span on the "
                      "requests track")
    return errors


def main(argv: list) -> int:
    if len(argv) != 2:
        print("usage: check_trace.py TRACE_JSON", file=sys.stderr)
        return 2
    errors = validate(argv[1])
    if errors:
        print(f"{len(errors)} trace problem(s) in {argv[1]}:")
        for e in errors:
            print(f"  {e}")
        return 1
    n = len(json.loads(Path(argv[1]).read_text())["traceEvents"])
    print(f"trace OK: {argv[1]} ({n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
