#!/usr/bin/env python
"""Check that intra-repo markdown links resolve.

Scans every ``*.md`` under the repo (skipping experiment output dirs)
for inline links ``[text](target)`` and validates:

* relative file targets exist (resolved against the linking file);
* ``#anchor`` fragments — same-file or cross-file — match a heading in
  the target markdown file (GitHub slug rules: lowercase, punctuation
  stripped, spaces to dashes);
* absolute-path targets are rejected (they break outside this checkout).

External links (http/https/mailto) are ignored. Exit code 1 with a
report if anything is broken — CI runs this so README/docs
cross-references cannot rot.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
SKIP_DIRS = {".git", "experiments", "__pycache__", ".github"}

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a heading line."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    return {slugify(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_file(md_path: Path) -> list:
    errors = []
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part.startswith("/"):
            errors.append(f"{md_path.relative_to(REPO)}: absolute link "
                          f"{target!r} (use a relative path)")
            continue
        dest = md_path if not path_part \
            else (md_path.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md_path.relative_to(REPO)}: broken link "
                          f"{target!r} -> {path_part} does not exist")
            continue
        if anchor:
            if dest.suffix != ".md":
                errors.append(f"{md_path.relative_to(REPO)}: anchor on "
                              f"non-markdown target {target!r}")
            elif slugify(anchor) not in anchors_of(dest):
                errors.append(f"{md_path.relative_to(REPO)}: anchor "
                              f"#{anchor} not found in "
                              f"{dest.relative_to(REPO)}")
    return errors


def main() -> int:
    md_files = [p for p in REPO.rglob("*.md")
                if not (set(p.relative_to(REPO).parts[:-1]) & SKIP_DIRS)]
    errors = []
    for p in sorted(md_files):
        errors.extend(check_file(p))
    if errors:
        print(f"{len(errors)} broken doc link(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"doc links OK: {len(md_files)} markdown files checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
