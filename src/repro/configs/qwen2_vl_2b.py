"""Qwen2-VL-2B — VLM decoder with M-RoPE; vision frontend STUB [arXiv:2409.12191].

The ViT encoder + merger is a stub per the assignment: ``input_specs()``
supplies pre-computed patch embeddings of shape (B, n_patches, d_model).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    act="swiglu",
    rope="mrope",           # 3-section rotary (temporal / height / width)
    rope_theta=1_000_000.0,
    frontend="vision",
    n_patches=256,          # stub: one 16x16-patch-grid image per sequence
    source="arXiv:2409.12191",
))
