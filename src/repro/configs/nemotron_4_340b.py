"""Nemotron-4-340B — dense decoder, GQA kv=8, squared-ReLU [arXiv:2402.16819]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    act="relu2",
    rope="rope",
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
))
