"""Hymba-1.5B — hybrid: parallel attention + mamba heads per block [arXiv:2411.13676]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,       # NOT divisible by 16 -> vocab replicated (see rules)
    act="swiglu",
    rope="rope",
    rope_theta=10_000.0,
    ssm_state=16,
    d_inner=3200,
    sliding_window=2048,    # hymba local attention
    source="arXiv:2411.13676",
))
