"""Whisper-tiny — enc-dec audio; mel+conv frontend STUB [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a stub per the assignment:
``input_specs()`` supplies pre-computed frame embeddings (B, 1500, d_model).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,        # NOT divisible by 16 -> vocab replicated (see rules)
    act="gelu",
    rope="learned",          # whisper uses learned positional embeddings
    cross_attention=True,
    frontend="audio",
    n_frames=1500,
    source="arXiv:2212.04356",
))
