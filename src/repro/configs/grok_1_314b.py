"""Grok-1 314B — MoE 8 experts top-2, GQA kv=8 [hf:xai-org/grok-1]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    act="geglu",
    rope="rope",
    rope_theta=10_000.0,
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    source="hf:xai-org/grok-1",
))
