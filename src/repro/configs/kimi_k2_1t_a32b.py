"""Kimi-K2 1T-A32B — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,           # d_model / n_heads
    d_ff=2048,
    vocab_size=163840,
    act="swiglu",
    rope="rope",
    rope_theta=50_000.0,
    n_experts=384,
    top_k=8,
    moe_d_ff=2048,
    capacity_factor=1.5,
    source="arXiv:2501.kimi2",
))
