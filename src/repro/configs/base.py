"""Architecture config schema + registry.

Every assigned architecture gets one module in this package defining a
full-size ``CONFIG`` (cited to its source paper / model card) plus the
family-preserving ``reduced()`` variant used by CPU smoke tests
(<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, replace
from typing import Any

import jax.numpy as jnp

ARCH_IDS = [
    "deepseek-7b",
    "qwen3-4b",
    "minitron-8b",
    "nemotron-4-340b",
    "rwkv6-1.6b",
    "grok-1-314b",
    "qwen2-vl-2b",
    "whisper-tiny",
    "kimi-k2-1t-a32b",
    "hymba-1.5b",
]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    act: str = "swiglu"          # swiglu | relu2 | gelu
    qk_norm: bool = False
    rope: str = "rope"           # rope | mrope | learned | none
    rope_theta: float = 1_000_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM / hybrid ---
    ssm_state: int = 0
    d_inner: int = 0             # 0 -> 2 * d_model
    # --- enc-dec / modality frontend (STUB: embeddings supplied) ---
    encoder_layers: int = 0
    n_frames: int = 0            # audio stub frame count
    n_patches: int = 0           # vision stub patch count (per image)
    frontend: str = "none"       # none | audio | vision
    cross_attention: bool = False
    # --- attention variant ---
    sliding_window: int = 0      # 0 = full causal attention
    attention_free: bool = False
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    remat: bool = True
    source: str = ""             # citation

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def dinner(self) -> int:
        return self.d_inner or (2 * self.d_model)

    @property
    def uses_attention(self) -> bool:
        return not self.attention_free

    @property
    def is_subquadratic(self) -> bool:
        """Can serve very long context without a windowed-attention override."""
        return self.attention_free or self.family in ("ssm",)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        nmat = 3 if self.act in ("swiglu", "geglu") else 2
        if self.n_experts:
            ffn = self.n_experts * nmat * d * self.moe_d_ff + d * self.n_experts
        else:
            ffn = nmat * d * self.d_ff
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di = self.dinner
            ssm = d * di * 2 + di * d + 2 * di * max(self.ssm_state, 1)
        per_layer = (attn if self.uses_attention else 0) + ffn + ssm + 2 * d
        enc = self.encoder_layers * (attn + nmat * d * self.d_ff + 2 * d)
        return emb + self.n_layers * per_layer + enc

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        nmat = 3 if self.act == "swiglu" else 2
        dense_ffn = self.top_k * nmat * d * self.moe_d_ff
        full_ffn = self.n_experts * nmat * d * self.moe_d_ff
        return self.n_params() - self.n_layers * (full_ffn - dense_ffn)

    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke-test variant (CPU, 1 device)."""
        d = min(self.d_model, 256)
        hd = 32
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(n_heads, self.n_kv_heads if self.n_kv_heads else n_heads))
        if self.n_heads == self.n_kv_heads:
            n_kv = n_heads  # preserve MHA-ness (deepseek)
        return replace(
            self,
            n_layers=2,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 256) if self.moe_d_ff else 0,
            d_inner=2 * d if self.family in ("ssm", "hybrid") else 0,
            encoder_layers=min(self.encoder_layers, 2),
            n_frames=min(self.n_frames, 16) if self.n_frames else 0,
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype=jnp.float32,
            remat=False,
        )


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ArchConfig]:
    for name in ARCH_IDS:
        get_config(name)
    return dict(_REGISTRY)
