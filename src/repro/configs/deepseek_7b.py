"""DeepSeek-LLM 7B — llama-arch dense decoder [arXiv:2401.02954]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,          # GQA kv=32 == MHA
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    act="swiglu",
    rope="rope",
    rope_theta=10_000.0,
    source="arXiv:2401.02954",
))
