"""Minitron-8B — pruned Nemotron-4 dense decoder [arXiv:2407.14679]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    act="relu2",            # squared-ReLU (nemotron family)
    rope="rope",
    rope_theta=10_000.0,
    source="arXiv:2407.14679",
))
