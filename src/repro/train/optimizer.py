"""AdamW + global-norm clipping + warmup-cosine schedule, from scratch.

State is a pytree mirroring params (mu, nu in f32) + a scalar step, so it
inherits the params' sharding under pjit.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    prog = (step - c.warmup_steps) / jnp.maximum(
        c.total_steps - c.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * jnp.where(step < c.warmup_steps, warm, cos)


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, c: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(c, step)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = c.b1 * mu + (1 - c.b1) * g
        nu = c.b2 * nu + (1 - c.b2) * jnp.square(g)
        u = (mu / b1c) / (jnp.sqrt(nu / b2c) + c.eps)
        u = u + c.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
