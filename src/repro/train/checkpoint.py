"""GridFS-style chunked checkpoint store (paper §3.2.3, adapted).

The paper stores large serialized models in MongoDB GridFS, which splits
any blob into fixed-size chunks. Our store does the same for pytrees:
each leaf is serialized and split into ``chunk_bytes`` files under
``<root>/<name>/chunks/``, with a JSON index (tree structure, dtypes,
shapes, chunk lists, checksums). Restore streams chunk-by-chunk, so a
leaf larger than memory never materializes twice, and integrity is
verified per chunk — the GridFS design point, without MongoDB.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path

import jax
import numpy as np

DEFAULT_CHUNK = 8 * 1024 * 1024   # GridFS default is 255KB; 8MB suits arrays


def _key(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def save(root, name: str, tree, *, chunk_bytes: int = DEFAULT_CHUNK,
         metadata: dict | None = None) -> dict:
    base = Path(root) / name
    cdir = base / "chunks"
    cdir.mkdir(parents=True, exist_ok=True)
    index: dict = {"leaves": {}, "metadata": metadata or {},
                   "chunk_bytes": chunk_bytes}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = _key(path)
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        chunks = []
        for i in range(0, max(len(raw), 1), chunk_bytes):
            blob = raw[i:i + chunk_bytes]
            digest = hashlib.sha256(blob).hexdigest()[:16]
            fname = f"{hashlib.md5(key.encode()).hexdigest()[:10]}.{i // chunk_bytes:05d}"
            (cdir / fname).write_bytes(blob)
            chunks.append({"file": fname, "sha": digest, "n": len(blob)})
        index["leaves"][key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "chunks": chunks,
        }
    (base / "index.json").write_text(json.dumps(index))
    return index


def restore(root, name: str, like=None) -> object:
    """Restore a checkpoint. ``like``: optional pytree prototype — restored
    leaves are validated against (and structured like) it; without it a
    flat {key: array} dict is returned."""
    base = Path(root) / name
    index = json.loads((base / "index.json").read_text())
    flat: dict[str, np.ndarray] = {}
    for key, meta in index["leaves"].items():
        buf = bytearray()
        for ch in meta["chunks"]:
            blob = (base / "chunks" / ch["file"]).read_bytes()
            if hashlib.sha256(blob).hexdigest()[:16] != ch["sha"]:
                raise IOError(f"checksum mismatch in {name}:{key}:{ch['file']}")
            if len(blob) != ch["n"]:
                raise IOError(f"truncated chunk in {name}:{key}")
            buf.extend(blob)
        arr = np.frombuffer(bytes(buf), dtype=np.dtype(meta["dtype"]))
        flat[key] = arr.reshape(meta["shape"])
    if like is None:
        return flat
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, proto in paths:
        key = _key(path)
        if key not in flat:
            raise KeyError(f"checkpoint {name} missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(proto)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(proto)}")
        leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def list_checkpoints(root) -> list[str]:
    root = Path(root)
    if not root.exists():
        return []
    return sorted(p.parent.name if p.parent.name != root.name else p.name
                  for p in root.glob("*/index.json"))
