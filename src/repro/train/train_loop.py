"""Training loop: jitted AdamW step (optionally pjit-sharded), metric
logging, periodic chunked checkpointing, deterministic resume."""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.train import checkpoint, optimizer as opt_mod
from repro.train.data import PackedLMDataset, sharded_batches


@dataclass
class TrainerConfig:
    n_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0          # 0 = only at the end
    ckpt_root: str = "checkpoints"
    ckpt_name: str = "run"
    opt: opt_mod.AdamWConfig = field(default_factory=opt_mod.AdamWConfig)


def make_train_step(model, oc: opt_mod.AdamWConfig, plan=None):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.train_loss(p, batch, plan)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_s, om = opt_mod.apply_updates(params, grads, opt_state, oc)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_p, new_s, metrics

    return train_step


@dataclass
class TrainResult:
    params: object
    opt_state: object
    history: list
    steps_per_s: float


def train(model, dataset: PackedLMDataset, tc: TrainerConfig, *,
          params=None, plan=None, start_step: int = 0,
          rng=None) -> TrainResult:
    rng = rng if rng is not None else jax.random.key(0)
    if params is None:
        params = model.init(rng)
    opt_state = opt_mod.init_state(params)
    step_fn = jax.jit(make_train_step(model, tc.opt, plan),
                      donate_argnums=(0, 1))

    history = []
    t0 = time.perf_counter()
    step = start_step
    for batch in sharded_batches(dataset, plan, tc.n_steps, start_step):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        step += 1
        if tc.log_every and (step % tc.log_every == 0 or step == start_step + 1):
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            history.append(m)
        if tc.ckpt_every and step % tc.ckpt_every == 0:
            checkpoint.save(tc.ckpt_root, f"{tc.ckpt_name}-{step}",
                            {"params": params},
                            metadata={"step": step})
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    checkpoint.save(tc.ckpt_root, f"{tc.ckpt_name}-final",
                    {"params": params}, metadata={"step": step})
    return TrainResult(params, opt_state, history,
                       (step - start_step) / max(dt, 1e-9))
