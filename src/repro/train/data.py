"""Synthetic LM data pipeline: document stream -> tokenize -> pack -> batch.

Deterministic, seekable (resume from a step counter), and sharding-aware:
``sharded_batches`` places each host batch with the plan's input sharding.
The CV corpus (repro.core.cvdata) doubles as the document source so the
end-to-end example trains on the paper's domain.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import cvdata


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    batch_size: int = 8
    seed: int = 0
    n_documents: int = 512


class PackedLMDataset:
    """Greedy sequence packing with EOS separators (no padding waste)."""

    EOS = 1

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        tok = cvdata.HashTokenizer(cfg.vocab_size)
        docs = cvdata.make_corpus(cfg.n_documents, seed=cfg.seed)
        stream: list[int] = []
        for d in docs:
            for s in d.sentences:
                stream.extend(tok.encode(s.tokens))
            stream.append(self.EOS)
        self.stream = np.asarray(stream, np.int32)

    def n_tokens(self) -> int:
        return len(self.stream)

    def batch(self, step: int) -> dict:
        """Deterministic batch for a global step (seekable resume)."""
        c = self.cfg
        span = c.seq_len + 1
        need = c.batch_size * span
        start = (step * need) % max(len(self.stream) - need, 1)
        flat = self.stream[start:start + need]
        if len(flat) < need:
            flat = np.concatenate([flat, self.stream[: need - len(flat)]])
        return {"tokens": flat.reshape(c.batch_size, span)}

    def batches(self, n_steps: int, start_step: int = 0):
        for s in range(start_step, start_step + n_steps):
            yield self.batch(s)


def sharded_batches(dataset: PackedLMDataset, plan, n_steps: int,
                    start_step: int = 0):
    """Device-put each batch with the plan's batch sharding."""
    import jax
    for b in dataset.batches(n_steps, start_step):
        if plan is None or plan.mesh is None:
            yield {k: jax.numpy.asarray(v) for k, v in b.items()}
        else:
            sh = plan.input_shardings(
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             b))
            yield jax.device_put(b, sh)
