"""Analytical Hierarchy Processing (AHP) — the paper's §3.1.3/§4.1 method
for selecting the serving substrate by multi-criteria decision making.

Structure: a goal, a set of criteria (pairwise-compared among themselves),
and a set of alternatives pairwise-compared w.r.t. each criterion. Each
pairwise matrix yields a priority vector (principal eigenvector, Saaty);
criteria weights combine the per-criterion priorities into final scores.

The paper's preference functions (§4.1):
    lower-is-better  (times):       pref(a1,a2) = min(9, max(1/9, a2/a1))
    higher-is-better (throughput):  pref(a1,a2) = min(9, max(1/9, a1/a2))
and all criteria weighted equally (pairwise preference 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

SAATY_RI = {1: 0.0, 2: 0.0, 3: 0.58, 4: 0.90, 5: 1.12, 6: 1.24, 7: 1.32,
            8: 1.41, 9: 1.45, 10: 1.49}


def clamp_preference(x: float) -> float:
    """Saaty scale clamp used by the paper: [1/9, 9]."""
    return min(9.0, max(1.0 / 9.0, x))


def lower_is_better(a1: float, a2: float) -> float:
    return clamp_preference(a2 / a1)


def higher_is_better(a1: float, a2: float) -> float:
    return clamp_preference(a1 / a2)


def pairwise_matrix(values, pref_fn) -> np.ndarray:
    n = len(values)
    m = np.ones((n, n))
    for i in range(n):
        for j in range(n):
            if i != j:
                m[i, j] = pref_fn(float(values[i]), float(values[j]))
    return m


def priority_vector(m: np.ndarray, iters: int = 200) -> np.ndarray:
    """Principal right-eigenvector by power iteration, normalized to sum 1."""
    n = m.shape[0]
    v = np.ones(n) / n
    for _ in range(iters):
        nv = m @ v
        nv = nv / nv.sum()
        if np.allclose(nv, v, rtol=1e-12, atol=1e-14):
            v = nv
            break
        v = nv
    return v


def consistency_ratio(m: np.ndarray) -> float:
    """Saaty CR = CI / RI; CR < 0.1 is conventionally acceptable."""
    n = m.shape[0]
    if n <= 2:
        return 0.0
    v = priority_vector(m)
    lam = float(np.mean((m @ v) / v))
    ci = (lam - n) / (n - 1)
    return ci / SAATY_RI.get(n, 1.49)


@dataclass
class Criterion:
    name: str
    higher_is_better: bool = True
    weight_votes: float = 1.0   # pairwise criteria preference (paper: all 1)


@dataclass
class AHPResult:
    alternatives: list
    criteria: list
    criteria_weights: np.ndarray          # (C,)
    per_criterion: np.ndarray             # (C, A) priorities
    scores: np.ndarray                    # (A,) final selection percentages
    consistency: dict = field(default_factory=dict)

    def ranking(self):
        order = np.argsort(-self.scores)
        return [(self.alternatives[i], float(self.scores[i])) for i in order]

    def table(self) -> str:
        """Markdown table in the paper's Tables 3-5 layout (criterion
        contribution per alternative)."""
        head = " | ".join(["criterion", "weight"] + list(self.alternatives))
        rows = [head, " | ".join(["---"] * (2 + len(self.alternatives)))]
        rows.append(" | ".join(
            ["TOTAL", "100%"] + [f"{s*100:.1f}%" for s in self.scores]))
        for ci, c in enumerate(self.criteria):
            contrib = self.criteria_weights[ci] * self.per_criterion[ci]
            rows.append(" | ".join(
                [c.name, f"{self.criteria_weights[ci]*100:.1f}%"]
                + [f"{x*100:.1f}%" for x in contrib]))
        return "\n".join(rows)


def run_ahp(alternatives: list, criteria: list, measurements) -> AHPResult:
    """measurements[c][a]: value of criterion c for alternative a
    (dict-of-dicts keyed by names, or a (C, A) array)."""
    C, A = len(criteria), len(alternatives)
    vals = np.zeros((C, A))
    for ci, c in enumerate(criteria):
        for ai, a in enumerate(alternatives):
            vals[ci, ai] = measurements[c.name][a] \
                if isinstance(measurements, dict) else measurements[ci][ai]

    # criteria pairwise matrix from weight votes (paper: all equal -> 1/C)
    crit_m = pairwise_matrix([c.weight_votes for c in criteria],
                             higher_is_better)
    cw = priority_vector(crit_m)

    per_c = np.zeros((C, A))
    consistency = {"criteria": consistency_ratio(crit_m)}
    for ci, c in enumerate(criteria):
        fn = higher_is_better if c.higher_is_better else lower_is_better
        m = pairwise_matrix(vals[ci], fn)
        per_c[ci] = priority_vector(m)
        consistency[c.name] = consistency_ratio(m)

    scores = cw @ per_c
    return AHPResult(list(alternatives), list(criteria), cw, per_c, scores,
                     consistency)


# ----------------------------------------------------------------- paper data
# Apache-Bench measurements from the paper's Table 2 (Verma & Prasad 2021).
PAPER_CRITERIA = [
    Criterion("Time per concurrent request", higher_is_better=False),
    Criterion("Requests per second", higher_is_better=True),
    Criterion("Time per request", higher_is_better=False),
    Criterion("Transfer rate", higher_is_better=True),
    Criterion("Total transferred", higher_is_better=True),
    Criterion("Time taken for tests", higher_is_better=False),
]

PAPER_TABLE2 = {
    "Hello World": {
        "Falcon":  {"Time per concurrent request": 23, "Requests per second": 4274,
                    "Time per request": 4, "Transfer rate": 680,
                    "Total transferred": 1_630_000, "Time taken for tests": 2},
        "FastApi": {"Time per concurrent request": 37, "Requests per second": 2650,
                    "Time per request": 7, "Transfer rate": 357,
                    "Total transferred": 1_380_000, "Time taken for tests": 3},
        "Flask":   {"Time per concurrent request": 84, "Requests per second": 1180,
                    "Time per request": 16, "Transfer rate": 190,
                    "Total transferred": 1_650_000, "Time taken for tests": 8},
    },
    "Finding value of Fibonacci": {
        "Falcon":  {"Time per concurrent request": 25, "Requests per second": 3969,
                    "Time per request": 5, "Transfer rate": 610,
                    "Total transferred": 1_730_000, "Time taken for tests": 2},
        "FastApi": {"Time per concurrent request": 38, "Requests per second": 2579,
                    "Time per request": 7, "Transfer rate": 372,
                    "Total transferred": 1_480_000, "Time taken for tests": 3},
        "Flask":   {"Time per concurrent request": 88, "Requests per second": 1126,
                    "Time per request": 17, "Transfer rate": 192,
                    "Total transferred": 1_750_000, "Time taken for tests": 8},
    },
    "File retrival from database": {
        "Falcon":  {"Time per concurrent request": 701, "Requests per second": 142,
                    "Time per request": 140, "Transfer rate": 22,
                    "Total transferred": 1_600_000, "Time taken for tests": 70},
        "FastApi": {"Time per concurrent request": 693, "Requests per second": 144,
                    "Time per request": 138, "Transfer rate": 19,
                    "Total transferred": 1_360_000, "Time taken for tests": 69},
        "Flask":   {"Time per concurrent request": 729, "Requests per second": 137,
                    "Time per request": 145, "Transfer rate": 21,
                    "Total transferred": 1_620_000, "Time taken for tests": 72},
    },
}

# Selection percentages the paper reports (Tables 3, 4, 5).
PAPER_RESULTS = {
    "Hello World": {"Falcon": 0.505, "FastApi": 0.317, "Flask": 0.178},
    "Finding value of Fibonacci": {"Falcon": 0.491, "FastApi": 0.330,
                                   "Flask": 0.179},
    "File retrival from database": {"Falcon": 0.341, "Flask": 0.332,
                                    "FastApi": 0.327},
}


def reproduce_paper_tables() -> dict:
    """Run AHP on the paper's own Table 2 -> per-scenario AHPResult."""
    out = {}
    for scenario, alt_vals in PAPER_TABLE2.items():
        alts = list(alt_vals)
        meas = {c.name: {a: alt_vals[a][c.name] for a in alts}
                for c in PAPER_CRITERIA}
        out[scenario] = run_ahp(alts, PAPER_CRITERIA, meas)
    return out
