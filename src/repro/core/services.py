"""Prediction-as-a-Service abstractions (paper §3.3).

A ``Service`` is a named prediction endpoint (one per CV section in the
paper; one per model in general). It is served by N ``Replica``s — the
paper deploys each PaaS on three machines, one marked ``backup``. Replicas
execute a handler; transport is in-process here (the pod analogue of the
paper's HTTP hop), with an optional latency model standing in for the
multi-machine cluster this container does not have.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable


class ServiceError(RuntimeError):
    """Replica-side failure: the balancer retries elsewhere and counts it
    against the replica's health (max_fails benching)."""


class RequestError(RuntimeError):
    """Client-side error (oversized prompt, expired deadline): retrying
    on another replica cannot help, so it propagates straight to the
    caller without touching replica health."""


@dataclass
class LatencyModel:
    """Stand-in for remote-machine service time (DESIGN.md §3 assumption 1).

    Lognormal-ish sampler parameterized by (median, p75) so the paper's
    Fig-7 per-service distributions can be plugged in directly.
    """
    median_s: float = 0.0
    p75_s: float = 0.0
    _rng: Any = field(default=None, repr=False)

    def sample(self, rng) -> float:
        import math
        if self.median_s <= 0:
            return 0.0
        mu = math.log(self.median_s)
        sigma = max(math.log(max(self.p75_s, self.median_s * 1.01))
                    - mu, 1e-3) / 0.6745
        return float(rng.lognormvariate(mu, sigma))


@dataclass
class Replica:
    """One deployment of a service (the paper: one machine:port)."""
    name: str
    handler: Callable[[Any], Any]
    backup: bool = False
    latency: LatencyModel | None = None
    fail_rate: float = 0.0          # fault injection for balancer tests
    max_concurrency: int = 0        # worker slots; 0 = unlimited
    _up: bool = True
    calls: int = 0
    failures: int = 0
    _slots: Any = field(default=None, repr=False)

    def __post_init__(self):
        if self.max_concurrency:
            self._slots = threading.Semaphore(self.max_concurrency)

    def healthy(self) -> bool:
        return self._up

    def load(self) -> int:
        """Current load for least-loaded balancing: delegates to the
        handler (engine-backed LM replicas report queue + active slots);
        plain handlers report 0 (round-robin ties)."""
        fn = getattr(self.handler, "load", None)
        return int(fn()) if callable(fn) else 0

    def set_up(self, up: bool) -> None:
        going_down = self._up and not up
        self._up = up
        if going_down:
            self.abort()

    def abort(self) -> None:
        """Kill in-flight work when the replica goes down: streaming
        handlers expose ``abort()`` to fail their open streams with a
        retryable ServiceError (the balancer will NOT replay a stream
        whose first token was already delivered — see
        ``core/balancer.py``). Plain handlers have nothing in flight."""
        fn = getattr(self.handler, "abort", None)
        if callable(fn):
            fn()

    def _serve(self, payload, rng):
        if self.latency is not None and rng is not None:
            time.sleep(self.latency.sample(rng))
        return self.handler(payload)

    def __call__(self, payload, rng=None):
        self.calls += 1
        if not self._up:
            self.failures += 1
            raise ServiceError(f"replica {self.name} is down")
        if self.fail_rate and rng is not None and rng.random() < self.fail_rate:
            self.failures += 1
            raise ServiceError(f"replica {self.name} transient failure")
        if self._slots is None:
            return self._serve(payload, rng)
        with self._slots:               # queue for a worker slot
            return self._serve(payload, rng)


@dataclass
class Service:
    """A named PaaS endpoint backed by replicas behind a balancer."""
    name: str
    replicas: list = field(default_factory=list)
    priority: int = 2               # supervisor start priority (paper §4.3)
    depends_on: tuple = ()
    started: bool = False
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)
    balancer: Any = None            # attached by deploy()

    def start(self) -> None:
        self.started = True

    def stop(self) -> None:
        self.started = False
        for r in self.replicas:
            r.abort()

    def __call__(self, payload, rng=None):
        if not self.started:
            raise ServiceError(f"service {self.name} not started")
        if self.balancer is None:
            # direct single-replica call
            return self.replicas[0](payload, rng)
        return self.balancer(payload, rng)
