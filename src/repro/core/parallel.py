"""Parallel vs sequential multi-service dispatch (paper §3.2.4 / §4.2).

The paper forks a ``multiprocessing.Process`` per section and joins the
results; its claim (Fig 8) is that parallel dispatch cuts the service
phase from 1.792 s to 0.568 s median (>3x). Here a dispatch is a list of
(service, payload) calls executed by one of three executors:

* ``sequential`` — the paper's monolithic baseline (one after another)
* ``thread``     — pool fan-out; overlaps the waiting on replicas, which
                   is the paper's situation (its PaaS are remote machines)
* ``jax_async``  — for in-process JAX services: enqueue every device
                   computation before blocking on any result, exploiting
                   JAX's asynchronous dispatch (TPU-adapted fan-out)

Process-per-request is deliberately NOT used: one runtime must own the
TPU devices (DESIGN.md §3, assumption 3).
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field


@dataclass
class DispatchResult:
    outputs: dict                      # call name -> output
    per_call_s: dict                   # call name -> service wall time
    total_s: float
    mode: str

    @property
    def sequential_equivalent_s(self) -> float:
        """Sum of per-call times = what a monolithic pipeline would pay."""
        return sum(self.per_call_s.values())

    @property
    def speedup(self) -> float:
        return self.sequential_equivalent_s / max(self.total_s, 1e-9)


@dataclass
class ParallelDispatcher:
    mode: str = "thread"               # thread | sequential | jax_async
    max_workers: int = 8
    rng: object = None                 # random.Random for latency models
    _pool: ThreadPoolExecutor = field(default=None, repr=False)

    def __post_init__(self):
        if self.mode == "thread":
            self._pool = ThreadPoolExecutor(max_workers=self.max_workers)

    def __call__(self, calls: list) -> DispatchResult:
        """calls: list of (name, service, payload)."""
        t0 = time.perf_counter()
        outputs: dict = {}
        timings: dict = {}

        def run_one(name, svc, payload):
            s = time.perf_counter()
            out = svc(payload, self.rng)
            timings[name] = time.perf_counter() - s
            return name, out

        if self.mode == "sequential":
            for name, svc, payload in calls:
                outputs[name] = run_one(name, svc, payload)[1]
        elif self.mode == "thread":
            futs = [self._pool.submit(run_one, *c) for c in calls]
            for f in futs:
                name, out = f.result()
                outputs[name] = out
        elif self.mode == "jax_async":
            import jax
            # enqueue everything (async dispatch), then block in order
            pending = []
            for name, svc, payload in calls:
                s = time.perf_counter()
                out = svc(payload, self.rng)       # returns un-blocked arrays
                pending.append((name, out, s))
            for name, out, s in pending:
                outputs[name] = jax.block_until_ready(out)
                timings[name] = time.perf_counter() - s
        else:
            raise ValueError(f"unknown dispatch mode {self.mode}")
        return DispatchResult(outputs, timings, time.perf_counter() - t0,
                              self.mode)

    def shutdown(self):
        if self._pool:
            self._pool.shutdown()
