"""Synthetic CV corpus (the paper's 50k-resume dataset is proprietary —
repro band 2: data gate simulated with a templated generator that emits
token-level BIO entity labels per section, per paper Table 1)."""
from __future__ import annotations

import random
from dataclasses import dataclass, field

from repro.core.router import SECTIONS

FIRST = ["amit", "priya", "rahul", "sneha", "vikram", "anita", "nikhil",
         "krishna", "meera", "arjun"]
LAST = ["verma", "prasad", "sharma", "gupta", "singh", "iyer", "das",
        "kumar", "patel", "rao"]
CITY = ["noida", "delhi", "mumbai", "bangalore", "pune", "chennai"]
DEGREE = ["btech", "mtech", "bsc", "msc", "mba", "phd"]
INSTITUTE = ["iit", "nit", "bits", "du", "amity", "vit"]
EMPLOYER = ["infoedge", "tcs", "infosys", "wipro", "flipkart", "paytm"]
DESIGNATION = ["engineer", "manager", "analyst", "architect", "lead",
               "scientist"]
SKILL = ["python", "java", "sql", "tensorflow", "jax", "kubernetes",
         "docker", "spark"]
ROLE = ["backend", "frontend", "devops", "research", "qa"]
INDUSTRY = ["software", "fintech", "ecommerce", "analytics"]
YEAR = [str(y) for y in range(2005, 2021)]
FILLER = ["the", "a", "with", "in", "at", "of", "and", "seeking", "worked",
          "completed", "from", "skilled", "to", "for", "experienced"]

# Per-section entity label sets (paper Table 1), BIO-less single tags + O.
SECTION_LABELS = {
    "personal_information": ["O", "NAME", "EMAIL", "PHONE", "CITY"],
    "education": ["O", "DEGREE", "INSTITUTE", "YEAR"],
    "work_experience": ["O", "DESIGNATION", "EMPLOYER", "YEAR"],
    "others": ["O", "SKILL", "ROLE", "INDUSTRY"],
}
# services consume merged sections; their label space is the union
SERVICE_LABELS = {
    "personal_information": SECTION_LABELS["personal_information"],
    "education": SECTION_LABELS["education"],
    "work_experience": SECTION_LABELS["work_experience"],
    "skills": ["O", "SKILL"],
    "functional_area": ["O", "ROLE", "INDUSTRY"],
}

MIMES = ["doc", "docx", "pdf"]


@dataclass
class Sentence:
    section: str
    tokens: list
    labels: list            # per-token entity tag names


@dataclass
class Document:
    mime: str
    sentences: list = field(default_factory=list)

    @property
    def text(self) -> str:
        return "\n".join(" ".join(s.tokens) for s in self.sentences)


def _sent(rng, section: str) -> Sentence:
    def pick(lst):
        return rng.choice(lst)

    toks: list = []
    labs: list = []

    def add(words, label="O"):
        for w in (words if isinstance(words, list) else [words]):
            toks.append(w)
            labs.append(label)

    if section == "personal_information":
        add(pick(FILLER))
        add(pick(FIRST), "NAME")
        add(pick(LAST), "NAME")
        add(pick(FILLER))
        add(f"{pick(FIRST)}@{pick(EMPLOYER)}.com", "EMAIL")
        add(str(rng.randint(6_000_000_000, 9_999_999_999)), "PHONE")
        add(pick(FILLER))
        add(pick(CITY), "CITY")
    elif section == "education":
        add([pick(FILLER), "completed"])
        add(pick(DEGREE), "DEGREE")
        add("from")
        add(pick(INSTITUTE), "INSTITUTE")
        add("in")
        add(pick(YEAR), "YEAR")
    elif section == "work_experience":
        add(["worked", "as"])
        add(pick(DESIGNATION), "DESIGNATION")
        add("at")
        add(pick(EMPLOYER), "EMPLOYER")
        add("since")
        add(pick(YEAR), "YEAR")
        if rng.random() < 0.5:
            add(["skilled", "in"])
            add(pick(SKILL), "SKILL")
    else:  # others
        add(["skilled", "in"])
        add(pick(SKILL), "SKILL")
        add("and")
        add(pick(SKILL), "SKILL")
        add(pick(FILLER))
        add(pick(ROLE), "ROLE")
        add(pick(INDUSTRY), "INDUSTRY")
    return Sentence(section, toks, labs)


def make_document(rng: random.Random) -> Document:
    doc = Document(mime=rng.choice(MIMES))
    for section in SECTIONS:
        for _ in range(rng.randint(1, 3)):
            doc.sentences.append(_sent(rng, section))
    rng.shuffle(doc.sentences)
    return doc


def make_corpus(n: int, seed: int = 0) -> list:
    rng = random.Random(seed)
    return [make_document(rng) for _ in range(n)]


# ---------------------------------------------------------------- tokenizer
class HashTokenizer:
    """Deterministic word -> id tokenizer (no external vocab files)."""

    def __init__(self, vocab_size: int = 4096):
        self.vocab_size = vocab_size

    def encode(self, words: list) -> list:
        import hashlib
        out = []
        for w in words:
            h = int(hashlib.md5(w.lower().encode()).hexdigest(), 16)
            out.append(2 + (h % (self.vocab_size - 2)))
        return out

    def pad(self, ids: list, length: int) -> list:
        ids = ids[:length]
        return ids + [0] * (length - len(ids))
