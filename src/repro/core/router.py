"""Section -> PaaS routing table (paper §4.2 step 3).

    (a) Personal Information section        -> Personal Information PaaS
    (b) Education section                   -> Education PaaS
    (c) Work Experience section             -> Work Experience PaaS
    (d) Work Experience + Others sections   -> Skills PaaS
    (e) Others section                      -> Functional Area PaaS
"""
from __future__ import annotations

SECTIONS = ("personal_information", "education", "work_experience", "others")

SECTION_CLASSES = {name: i for i, name in enumerate(SECTIONS)}

ROUTES: dict[str, tuple[str, ...]] = {
    "personal_information": ("personal_information",),
    "education": ("education",),
    "work_experience": ("work_experience",),
    "skills": ("work_experience", "others"),
    "functional_area": ("others",),
}


def route(sectioned: dict) -> dict:
    """sectioned: {section_name: payload-list}. Returns
    {service_name: payload-list} following the paper's fan-out map."""
    out = {}
    for svc, secs in ROUTES.items():
        merged: list = []
        for s in secs:
            merged.extend(sectioned.get(s, []))
        out[svc] = merged
    return out
