"""NGINX-upstream semantics, in process (paper §3.3.1 / §4.3).

Reproduces the paper's upstream block:

    upstream parser-independent-PaaS {
        server ip1:p1 max_fails=3 fail_timeout=15s;
        server ip2:p2 max_fails=3 fail_timeout=15s;
        server ip3:p3 backup;
    }

Round-robin over healthy primaries; a primary that fails ``max_fails``
times inside a ``fail_timeout`` window is benched for ``fail_timeout``
seconds; the ``backup`` replica only serves while ALL primaries are
benched/down.

``policy="least_loaded"`` (NGINX ``least_conn`` analogue) routes each
request to the candidate reporting the smallest ``Replica.load()`` —
engine-backed LM replicas report queue depth + occupied slots, so long
generations stop head-of-line-blocking the other replicas.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.core.services import Replica, ServiceError


@dataclass
class _ReplicaState:
    fails: list = field(default_factory=list)   # failure timestamps
    benched_until: float = 0.0


class RoundRobinBalancer:
    def __init__(self, replicas: list[Replica], *, max_fails: int = 3,
                 fail_timeout: float = 15.0, clock=time.monotonic,
                 policy: str = "rr"):
        assert policy in ("rr", "least_loaded"), policy
        self.primaries = [r for r in replicas if not r.backup]
        self.backups = [r for r in replicas if r.backup]
        if not self.primaries:
            raise ValueError("need at least one primary replica")
        self.max_fails = max_fails
        self.fail_timeout = fail_timeout
        self.clock = clock
        self.policy = policy
        self._rr = 0
        self._lock = threading.Lock()
        self._state = {id(r): _ReplicaState() for r in replicas}
        self.stats = {"served": 0, "failovers": 0, "backup_served": 0}

    # ----------------------------------------------------------- metrics
    def metrics_snapshot(self) -> dict:
        """Upstream counters plus current bench state, flat and numeric
        — the shape ``MetricsRegistry.source`` polls, and what
        ``Supervisor.snapshot``/``status`` surface per service."""
        with self._lock:
            now = self.clock()
            return {**self.stats,
                    "benched": sum(1 for st in self._state.values()
                                   if st.benched_until > now),
                    "primaries": len(self.primaries),
                    "backups": len(self.backups)}

    # ----------------------------------------------------------- selection
    def _available(self, r: Replica) -> bool:
        return self._state[id(r)].benched_until <= self.clock()

    def _candidates(self) -> list[Replica]:
        prim = [r for r in self.primaries if self._available(r)]
        if prim:
            return prim
        return [r for r in self.backups if self._available(r)]

    def _record_failure(self, r: Replica) -> None:
        st = self._state[id(r)]
        now = self.clock()
        st.fails = [t for t in st.fails if now - t < self.fail_timeout]
        st.fails.append(now)
        if len(st.fails) >= self.max_fails:
            st.benched_until = now + self.fail_timeout
            st.fails = []

    # ----------------------------------------------------------- dispatch
    def __call__(self, payload, rng=None):
        attempts = 0
        last_err: Exception | None = None
        # a request may retry a failing primary until it crosses max_fails
        # and gets benched (then the backup pool takes over)
        budget = self.max_fails * len(self.primaries) + len(self.backups) + 1
        # streaming payloads carry an "on_token" callback. Each attempt
        # wraps it with a fresh delivery counter: a ServiceError BEFORE
        # the first token is an ordinary failover (the client observed
        # nothing), but once a token has streamed the request is NOT
        # replayed — a retry would re-deliver a divergent-length prefix
        # to a client that already consumed part of the stream. The
        # failure still counts against the replica's health.
        on_token = payload.get("on_token") if isinstance(payload, dict) \
            else None
        while attempts < budget:
            with self._lock:
                cands = self._candidates()
                if not cands:
                    break
                if self.policy == "least_loaded":
                    r = min(cands, key=lambda c: c.load())
                else:
                    r = cands[self._rr % len(cands)]
                self._rr += 1
            streamed = 0
            if on_token is not None:
                def _counting(tok, logp, _inner=on_token):
                    nonlocal streamed
                    _inner(tok, logp)
                    streamed += 1
                payload = dict(payload, on_token=_counting)
            try:
                out = r(payload, rng)
                with self._lock:
                    self.stats["served"] += 1
                    if r.backup:
                        self.stats["backup_served"] += 1
                return out
            except ServiceError as e:
                last_err = e
                attempts += 1
                with self._lock:
                    self._record_failure(r)
                    self.stats["failovers"] += 1
                if streamed:
                    raise ServiceError(
                        f"replica failed after streaming {streamed} "
                        f"tokens; not retrying a partially-delivered "
                        f"stream ({e})") from e
        raise ServiceError(
            f"all replicas unavailable ({last_err})") from last_err


def deploy(service, *, max_fails: int = 3, fail_timeout: float = 15.0,
           clock=time.monotonic, policy: str = "rr"):
    """Attach an upstream balancer to a Service (paper's single-uri
    upstreaming)."""
    service.balancer = RoundRobinBalancer(
        service.replicas, max_fails=max_fails, fail_timeout=fail_timeout,
        clock=clock, policy=policy)
    return service
