"""The CV-Parser pipeline (paper Fig 5): extract -> embed -> section ->
parallel per-section NER PaaS -> join.

Every stage is a real JAX model (no stubs except the Tika byte-format
handling, which reduces to reading the synthetic Document's text). Stage
timings are recorded exactly as the paper's Table 6 (tika / sectioning /
bert / parallel-services).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cvdata, router
from repro.core.cvdata import SERVICE_LABELS, HashTokenizer
from repro.core.parallel import ParallelDispatcher
from repro.core.services import Service, Replica
from repro.models import bert_encoder, bilstm_lan

MAX_SENT_LEN = 24


# ------------------------------------------------------------- tika (stub)
class TextExtractor:
    """Apache-Tika stand-in: mime detection + text extraction. The paper
    treats Tika as a black-box service; our synthetic documents carry
    their text, so extraction is parsing the Document container."""

    SUPPORTED = set(cvdata.MIMES) | {"txt", "rtf", "odt"}

    def extract(self, document) -> list:
        if document.mime not in self.SUPPORTED:
            raise ValueError(f"unsupported mime {document.mime}")
        return [s.tokens for s in document.sentences]


# ------------------------------------------------------------- NER service
@dataclass
class NERModel:
    name: str
    cfg: bilstm_lan.LANConfig
    params: dict
    tokenizer: HashTokenizer
    _predict: callable = field(default=None, repr=False)

    @classmethod
    def create(cls, name: str, rng, vocab_size=4096):
        labels = SERVICE_LABELS[name]
        cfg = bilstm_lan.LANConfig(vocab_size=vocab_size,
                                   n_labels=len(labels))
        params = bilstm_lan.init_params(rng, cfg)
        return cls(name, cfg, params, HashTokenizer(vocab_size))

    def __post_init__(self):
        self._predict = jax.jit(
            lambda p, t: bilstm_lan.predict(p, self.cfg, t))

    def __call__(self, sentences: list) -> list:
        """sentences: list of token lists -> list of (token, label) pairs."""
        if not sentences:
            return []
        labels = SERVICE_LABELS[self.name]
        ids = np.array([self.tokenizer.pad(self.tokenizer.encode(s),
                                           MAX_SENT_LEN)
                        for s in sentences], np.int32)
        n = len(sentences)
        bucket = max(4, 1 << (n - 1).bit_length())      # shape bucketing
        if n < bucket:
            ids = np.pad(ids, ((0, bucket - n), (0, 0)))
        pred = np.asarray(self._predict(self.params, jnp.asarray(ids)))[:n]
        out = []
        for si, s in enumerate(sentences):
            for ti, tok in enumerate(s[:MAX_SENT_LEN]):
                lab = labels[int(pred[si, ti])]
                if lab != "O":
                    out.append((tok, lab))
        return out


# ------------------------------------------------------------- the parser
@dataclass
class CVParser:
    extractor: TextExtractor
    encoder_cfg: object
    encoder_params: dict
    classifier_params: dict
    services: dict                   # service name -> Service
    dispatcher: ParallelDispatcher
    tokenizer: HashTokenizer
    _embed: callable = field(default=None, repr=False)
    _classify: callable = field(default=None, repr=False)

    @classmethod
    def create(cls, rng=None, dispatcher=None, services=None,
               vocab_size=4096):
        rng = rng if rng is not None else jax.random.key(0)
        ks = jax.random.split(rng, 8)
        enc_cfg = bert_encoder.encoder_config(vocab_size)
        enc = bert_encoder.init_encoder(ks[0], enc_cfg)
        clf = bert_encoder.init_classifier(ks[1])
        if services is None:
            services = {}
            for i, name in enumerate(router.ROUTES):
                ner = NERModel.create(name, ks[2 + i], vocab_size)
                services[name] = Service(
                    name, replicas=[Replica(f"{name}/0", ner)], priority=2)
                services[name].start()
        return cls(TextExtractor(), enc_cfg, enc, clf, services,
                   dispatcher or ParallelDispatcher(mode="thread"),
                   HashTokenizer(vocab_size))

    def __post_init__(self):
        self._embed = jax.jit(
            lambda p, t, m: bert_encoder.encode_sentences(
                p, self.encoder_cfg, t, m))
        self._classify = jax.jit(bert_encoder.classify_sections)

    # ------------------------------------------------------------ stages
    def parse(self, document) -> dict:
        """Returns {"fields": ..., "timings": {tika, sectioning, bert,
        parallel_services, total}, "dispatch": DispatchResult}."""
        t_start = time.perf_counter()
        timings = {}

        t0 = time.perf_counter()
        sentences = self.extractor.extract(document)
        timings["tika"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        ids = np.array([self.tokenizer.pad(self.tokenizer.encode(s),
                                           MAX_SENT_LEN)
                        for s in sentences], np.int32)
        # bucket the sentence-batch dim so jit compiles once per bucket,
        # not once per distinct CV length (shape-bucketing, serving 101)
        n = len(sentences)
        bucket = max(8, 1 << (n - 1).bit_length())
        if n < bucket:
            ids = np.pad(ids, ((0, bucket - n), (0, 0)))
        mask = (ids != 0)
        emb = self._embed(self.encoder_params, jnp.asarray(ids),
                          jnp.asarray(mask))
        emb = jax.block_until_ready(emb)[:n]
        timings["bert"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        logits = self._classify(self.classifier_params, emb)
        section_ids = np.asarray(jnp.argmax(logits, axis=-1))
        timings["sectioning"] = time.perf_counter() - t0

        sectioned: dict = {s: [] for s in router.SECTIONS}
        for s_id, sent in zip(section_ids, sentences):
            sectioned[router.SECTIONS[int(s_id)]].append(sent)

        t0 = time.perf_counter()
        fanout = router.route(sectioned)
        calls = [(name, self.services[name], payload)
                 for name, payload in fanout.items()]
        result = self.dispatcher(calls)
        timings["parallel_services"] = time.perf_counter() - t0
        timings["total"] = time.perf_counter() - t_start

        fields = {name: result.outputs[name] for name, _, _ in calls}
        return {"fields": fields, "timings": timings, "dispatch": result}
