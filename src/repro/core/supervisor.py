"""supervisord semantics, in process (paper §3.3.1 / §4.3).

The paper's supervisor.conf starts services in priority order:
    0: Tika (text extraction)   1: BERT encoder
    2: per-section PaaS         3: CV-Parser front-end
with auto-restart. This module reproduces: priority-ordered startup,
dependency verification (a service never starts before everything at a
lower priority / in ``depends_on`` is up), restart-with-backoff, and a
``supervisorctl``-style status view.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.services import Service, ServiceError


@dataclass
class Supervisor:
    services: dict = field(default_factory=dict)
    max_restarts: int = 3
    backoff_s: float = 0.0          # 0 in tests; supervisord default 1s
    # injectable so tests drive restart backoff on a virtual clock
    # (VirtualClock.sleep records and advances instead of blocking)
    sleep: object = time.sleep
    events: list = field(default_factory=list)
    # restart accounting, surfaced by snapshot(): per-service failed
    # start attempts (across every _start call's retries), and the
    # services that ever exhausted their max_restarts budget
    restart_attempts: dict = field(default_factory=dict)
    exhausted: set = field(default_factory=set)

    def add(self, svc: Service) -> Service:
        self.services[svc.name] = svc
        return svc

    # ------------------------------------------------------------- startup
    def start_all(self) -> list[str]:
        """Start every service in (priority, insertion) order, verifying
        dependencies. Returns the startup order."""
        order = sorted(self.services.values(),
                       key=lambda s: (s.priority,
                                      list(self.services).index(s.name)))
        started: list[str] = []
        for svc in order:
            for dep in svc.depends_on:
                if dep not in self.services:
                    raise ServiceError(f"{svc.name}: unknown dependency {dep}")
                if not self.services[dep].started:
                    raise ServiceError(
                        f"{svc.name}: dependency {dep} not started "
                        f"(priority ordering violated)")
            self._start(svc)
            started.append(svc.name)
        return started

    def _start(self, svc: Service) -> None:
        attempts = 0
        while True:
            try:
                svc.start()
                self.events.append(("started", svc.name, attempts))
                return
            except Exception:  # noqa: BLE001 — supervisor retries anything
                attempts += 1
                self.restart_attempts[svc.name] = \
                    self.restart_attempts.get(svc.name, 0) + 1
                self.events.append(("start-failed", svc.name, attempts))
                if attempts > self.max_restarts:
                    self.exhausted.add(svc.name)
                    raise
                if self.backoff_s:
                    self.sleep(self.backoff_s * attempts)

    # ------------------------------------------------------------- control
    def restart(self, name: str) -> None:
        svc = self.services[name]
        svc.stop()
        self._start(svc)

    def stop_all(self) -> None:
        for svc in reversed(list(self.services.values())):
            svc.stop()
            self.events.append(("stopped", svc.name, 0))

    def status(self) -> dict:
        """supervisorctl status analogue, enriched with replica health
        and upstream (balancer) counters when a service is deployed."""
        out = {}
        for name, s in self.services.items():
            row = {
                "state": "RUNNING" if s.started else "STOPPED",
                "priority": s.priority,
                "replicas": len(s.replicas),
                "healthy_replicas": sum(1 for r in s.replicas if r.healthy()),
                "load": sum(r.load() for r in s.replicas),
            }
            if s.balancer is not None:
                row["upstream"] = dict(s.balancer.stats)
            out[name] = row
        return out

    def snapshot(self) -> dict:
        """``status()`` enriched with restart accounting — per-service
        failed start attempts and whether the restart budget was ever
        exhausted — plus the supervisor-wide budget, so a fleet
        dashboard sees flapping services before they die for good."""
        out = self.status()
        for name, row in out.items():
            row["restart_attempts"] = self.restart_attempts.get(name, 0)
            row["restarts_exhausted"] = name in self.exhausted
            row["max_restarts"] = self.max_restarts
        return out

    def prometheus_text(self) -> str:
        """One Prometheus exposition across every deployed service:
        each LM replica's metrics registry (labelled per replica),
        each balancer's upstream counters (labelled per service), and
        the supervisor's own restart accounting — the fleet-level
        scrape endpoint."""
        from repro.serve.telemetry import MetricsRegistry, prometheus_text
        regs = []
        for name, s in self.services.items():
            for r in s.replicas:
                reg = getattr(r.handler, "registry", None)
                if reg is not None:
                    regs.append(reg)
            bal = getattr(s, "balancer", None)
            if bal is not None and hasattr(bal, "metrics_snapshot"):
                breg = MetricsRegistry(labels={"service": name})
                breg.source("balancer", bal.metrics_snapshot)
                regs.append(breg)
            sreg = MetricsRegistry(labels={"service": name})
            sreg.source("supervisor", lambda n=name: {
                "restart_attempts": self.restart_attempts.get(n, 0),
                "restarts_exhausted":
                    1 if n in self.exhausted else 0,
                "max_restarts": self.max_restarts,
                "up": 1 if self.services[n].started else 0})
            regs.append(sreg)
        return prometheus_text(regs)

    def unhealthy(self) -> list[str]:
        """Services with zero healthy replicas — restart candidates."""
        return [name for name, s in self.services.items()
                if s.started and s.replicas
                and not any(r.healthy() for r in s.replicas)]
