"""Mesh space-sharing: the paper's parallel PaaS, TPU-adapted (DESIGN §3).

The paper gives every section-NER its own machines; the pod analogue is
giving every model service a disjoint slice of the device mesh. Each
service's step function is jitted against its own sub-mesh; because JAX
dispatch is asynchronous, enqueueing all services' computations before
blocking on any result runs them concurrently on their disjoint device
groups — one host thread, K models in flight (the paper's
`multiprocessing` fan-out without host processes).

With fewer devices than services (this CPU container) the groups overlap
and space-sharing degenerates to time-sharing; the dispatch/join logic is
identical, which is what the tests exercise.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class ModelService:
    name: str
    step_fn: callable              # (params, batch) -> output
    params: object
    jitted: callable = field(default=None, repr=False)
    submesh: Mesh = None


class MultiModelServer:
    """Partition a mesh's leading axis into per-service groups."""

    def __init__(self, services: list, devices=None, axis_names=("data",)):
        devices = list(devices if devices is not None else jax.devices())
        k = len(services)
        self.services: dict[str, ModelService] = {}
        groups = self._partition(devices, k)
        for svc, devs in zip(services, groups):
            submesh = Mesh(np.array(devs).reshape(len(devs),
                                                  *([1] * (len(axis_names) - 1))),
                           axis_names)
            repl = NamedSharding(submesh, P())
            svc.submesh = submesh
            svc.jitted = jax.jit(svc.step_fn,
                                 in_shardings=(repl, repl),
                                 out_shardings=repl)
            self.services[svc.name] = svc
        self.stats = {"parallel_calls": 0, "sequential_calls": 0}

    @staticmethod
    def _partition(devices: list, k: int) -> list:
        n = len(devices)
        if n >= k:
            per = n // k
            return [devices[i * per:(i + 1) * per] for i in range(k)]
        # degenerate: overlap groups (time-sharing)
        return [[devices[i % n]] for i in range(k)]

    # ------------------------------------------------------------ serving
    def _put(self, svc: ModelService, batch):
        repl = NamedSharding(svc.submesh, P())
        return jax.device_put(batch, repl)

    def serve_parallel(self, batches: dict) -> tuple[dict, float]:
        """Enqueue every service, then join (paper's parallel calling)."""
        t0 = time.perf_counter()
        pending = {}
        for name, batch in batches.items():
            svc = self.services[name]
            pending[name] = svc.jitted(svc.params, self._put(svc, batch))
        out = {n: jax.block_until_ready(o) for n, o in pending.items()}
        self.stats["parallel_calls"] += 1
        return out, time.perf_counter() - t0

    def serve_sequential(self, batches: dict) -> tuple[dict, float]:
        """Block after each service (paper's monolithic baseline)."""
        t0 = time.perf_counter()
        out = {}
        for name, batch in batches.items():
            svc = self.services[name]
            out[name] = jax.block_until_ready(
                svc.jitted(svc.params, self._put(svc, batch)))
        self.stats["sequential_calls"] += 1
        return out, time.perf_counter() - t0

    # ------------------------------------------------------------ dry-run
    def lower_all(self, batch_specs: dict) -> dict:
        """.lower().compile() every service on its sub-mesh (validation)."""
        out = {}
        for name, spec in batch_specs.items():
            svc = self.services[name]
            params_s = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), svc.params)
            out[name] = svc.jitted.lower(params_s, spec).compile()
        return out
