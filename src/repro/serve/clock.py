"""Virtual time for deterministic serving tests and harnesses.

Every clock-bearing component in the serving stack (engine, scheduler,
balancer, supervisor) takes an injectable ``clock`` — a zero-argument
callable returning seconds, ``time.perf_counter`` by default. A
:class:`VirtualClock` satisfies the same protocol but only moves when
the test advances it, so deadline/EDF shedding, SLO accounting, restart
backoff, and the async serve loop's arrival traces are exercised
without a single wall-clock sleep: a slow CI host cannot expire a
deadline the test meant to be live, and a test that "waits" 500 s
finishes instantly.

The clock is deliberately *passive* (no event queue): the serving stack
polls time, it never sleeps on it, so ``advance`` between loop ticks is
all a harness needs. ``sleep`` exists for components that back off
(supervisor restarts) — it advances instead of blocking.
"""
from __future__ import annotations


class VirtualClock:
    """Deterministic, manually-advanced clock.

    Callable like ``time.perf_counter`` (the protocol every serving
    component's ``clock`` parameter expects); ``advance``/``sleep`` move
    it forward. Never blocks, never goes backwards.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self.sleeps: list[float] = []      # every sleep(dt), for asserts

    def __call__(self) -> float:
        return self._t

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clocks only move forward, got {dt}")
        self._t += dt
        return self._t

    def sleep(self, dt: float) -> None:
        """Drop-in for ``time.sleep`` that advances instead of blocking
        (and records the request, so tests can assert backoff behaviour
        without paying for it)."""
        self.sleeps.append(dt)
        self.advance(max(dt, 0.0))
