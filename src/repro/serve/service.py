"""Wire a ServingEngine + Scheduler into the paper's PaaS fabric.

A language model becomes one more Prediction-as-a-Service endpoint: N
engine-backed replicas behind the NGINX-style balancer, started by the
supervisor in priority order next to Tika/BERT/NER services. Each
replica owns its own slot-native engine (own KV cache), so replicas
scale serving capacity the same way the paper scales section parsers
across machines.

Payloads are ``{"prompt": [...], "max_new_tokens": n, ...}`` dicts;
the reply carries the generated tokens plus per-request latency so the
front-end can report Table-6-style stage timings. A payload may carry an
``"on_token"`` callable — the replica then streams every generated
``(token, logprob)`` to it as decode ticks commit, instead of the client
seeing output only at completion (see ``docs/serving.md``).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any

from repro.core.balancer import deploy
from repro.core.services import (Replica, RequestError, Service,
                                 ServiceError)
from repro.serve.async_loop import AsyncServeLoop
from repro.serve.engine import Request, ServingEngine
from repro.serve.sampling import GREEDY, SamplingParams
from repro.serve.scheduler import Scheduler
from repro.serve.telemetry import MetricsRegistry, prometheus_text


@dataclass
class LMReplica:
    """One engine-backed deployment of an LM service.

    Each replica owns an :class:`AsyncServeLoop` pumping its engine as a
    dispatch → plan-ahead → commit pipeline; ``__call__`` stays a
    synchronous handler (submit a stream handle, pump until it
    resolves) to match the in-process transport of the other PaaS
    replicas, while ``"on_token"`` payloads observe tokens per tick.
    ``load()`` exposes intake + queue depth + occupied slots so the
    balancer can route least-loaded.
    """
    name: str
    scheduler: Scheduler
    _rid: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)
    loop: AsyncServeLoop = field(init=False, repr=False)
    registry: MetricsRegistry = field(init=False, repr=False)

    def __post_init__(self):
        self.loop = AsyncServeLoop(self.scheduler, name=self.name)
        # one metrics namespace per replica, labelled by replica name so
        # expositions from many replicas merge without collisions. The
        # engine/pool/scheduler/loop stats dicts stay the single source
        # of truth — the registry polls them at collection time.
        eng = self.scheduler.engine
        self.registry = MetricsRegistry(labels={"replica": self.name})
        self.registry.source("engine", lambda: eng.metrics)
        self.registry.source("pool", eng.pool_stats)
        self.registry.source("loop", lambda: self.loop.metrics)
        self.registry.source("scheduler", self._scheduler_metrics)

    def _scheduler_metrics(self) -> dict:
        st = self.scheduler.stats
        return {"admitted": st.admitted, "completed": st.completed,
                "rejected": st.rejected, "shed": st.shed,
                "ticks": st.ticks, "queue_peak": st.queue_peak,
                "queue_depth": len(self.scheduler.queue),
                "slo_hits": st.slo_hits, "slo_misses": st.slo_misses,
                "planned_ahead": st.planned_ahead,
                "plan_hits": st.plan_hits,
                "latency_p50_s": st.percentile(0.50),
                "latency_p99_s": st.percentile(0.99),
                "queue_wait_mean_s": st.mean_queue_wait_s()}

    def prometheus_text(self) -> str:
        """This replica's metrics as one Prometheus text exposition."""
        return self.registry.prometheus_text()

    def load(self) -> int:
        return self.loop.load()

    def abort(self) -> int:
        """Fail all in-flight streams with a retryable ServiceError and
        reset serving state — called when the replica is stopped or
        marked down mid-stream (supervisor restart, health flip)."""
        return self.loop.abort()

    def _parse(self, payload: dict, rid: int) -> Request:
        samp = payload.get("sampling", GREEDY)
        if isinstance(samp, dict):
            try:
                samp = SamplingParams(**samp)
            except TypeError as e:
                # client error: no other replica can parse it either
                raise RequestError(f"{self.name}: bad sampling "
                                   f"params {samp!r}: {e}") from e
        if not isinstance(samp, SamplingParams):
            raise RequestError(f"{self.name}: \"sampling\" must be a "
                               f"dict or SamplingParams, got "
                               f"{type(samp).__name__}")
        spec = payload.get("speculation")
        if spec is not None and (isinstance(spec, bool)
                                 or not isinstance(spec, int)
                                 or spec < 0):
            # same client-error contract as "sampling": a value the
            # engine would choke on mid-tick must not look like a
            # replica failure to the balancer
            raise RequestError(f"{self.name}: \"speculation\" must be "
                               f"a non-negative int, got {spec!r}")
        chunk = payload.get("prefill_chunk")
        if chunk is not None and (isinstance(chunk, bool)
                                  or not isinstance(chunk, int)
                                  or chunk < 1):
            # the payload contract is positive-int-or-absent (absent
            # = engine default); non-positive values are a client
            # error, not a replica failure. (Engine-internal
            # Request.prefill_chunk=0 is a valid monolithic opt-out;
            # the HTTP-ish payload deliberately doesn't expose it.)
            raise RequestError(f"{self.name}: \"prefill_chunk\" must "
                               f"be a positive int, got {chunk!r}")
        req = Request(rid=rid, prompt=list(payload["prompt"]),
                      max_new_tokens=payload.get("max_new_tokens", 8),
                      stop_tokens=tuple(payload.get("stop_tokens", ())),
                      priority=payload.get("priority", 0),
                      deadline_s=payload.get("deadline_s"),
                      sampling=samp,
                      speculation=payload.get("speculation"),
                      prefill_chunk=chunk)
        # latency and deadlines live on the scheduler's timeline
        # (virtual in tests, perf_counter in production)
        req.submitted_s = self.scheduler.clock()
        # client errors: no other replica can serve these either, so
        # they must NOT look like replica failures to the balancer
        eng = self.scheduler.engine
        if len(req.prompt) > eng.max_seq:
            raise RequestError(f"{self.name}: prompt length "
                               f"{len(req.prompt)} > max_seq "
                               f"{eng.max_seq}")
        if eng.paged and eng.blocks_worst_case(req) > eng.pool.total:
            raise RequestError(f"{self.name}: prompt needs "
                               f"{eng.blocks_worst_case(req)} KV blocks "
                               f"> pool total {eng.pool.total}")
        if req.deadline_s is not None \
                and req.deadline_s <= self.scheduler.clock():
            raise RequestError(f"{self.name}: deadline already expired")
        return req

    def submit(self, payload: dict):
        """Validate a payload and hand it to the serve loop; returns the
        StreamHandle (callers that want the blocking contract use
        ``__call__``)."""
        with self._lock:
            self._rid += 1
            rid = self._rid
        on_token = payload.get("on_token")
        if on_token is not None and not callable(on_token):
            raise RequestError(f"{self.name}: \"on_token\" must be "
                               f"callable, got {type(on_token).__name__}")
        req = self._parse(payload, rid)
        return self.loop.submit(req, on_token)

    def __call__(self, payload: dict) -> dict:
        # queue-full surfaces from the loop as a retryable ServiceError;
        # sheds and disconnects as RequestError — same taxonomy the
        # drain-based handler had
        return self.loop.wait(self.submit(payload))


def make_lm_service(name: str, model, params, *, n_replicas: int = 1,
                    batch_size: int = 4, max_seq: int = 128,
                    policy: str = "fifo", max_queue: int = 0,
                    priority: int = 2, depends_on: tuple = (),
                    supervisor: Any = None, balancer_policy: str = "rr",
                    with_backup: bool = True, plan=None,
                    paged: bool | None = None, block_size: int = 16,
                    num_blocks: int | None = None,
                    pressure_shed: float | None = None,
                    prefix_sharing: bool = True,
                    use_kernel: bool = False, draft_model=None,
                    draft_params=None, speculation: int = 0,
                    prefill_chunk: int | None = None,
                    prefill_budget: int | None = None,
                    tracer=None) -> Service:
    """Build an LM PaaS: engine replicas -> Replica -> Service -> balancer,
    optionally registered with a Supervisor (started in priority order).

    ``paged``/``block_size``/``num_blocks`` configure each replica's KV
    block pool (paged by default for pure-attention families);
    ``pressure_shed`` arms the scheduler's memory-pressure shedding;
    ``prefix_sharing`` lets admissions reuse resident prompt-prefix
    blocks copy-on-write (on by default for non-MoE paged engines);
    ``use_kernel`` switches paged decode from the jnp gather to the
    in-place Pallas paged-attention kernel (interpret mode off-TPU).
    ``draft_model``/``draft_params``/``speculation=k`` arm speculative
    draft-and-verify decode: every replica owns a draft replica of the
    small model and verifies its k proposals per slot in one multi-token
    target step (requests opt out — or down — with a ``"speculation"``
    payload key; ``"sampling"`` carries per-request
    temperature/top_k/seed, and the reply streams per-token logprobs).
    ``prefill_chunk`` sets each engine's chunked-prefill width (None =
    the engine default for chunkable families; 0 = monolithic
    admission; requests override per-call with a ``"prefill_chunk"``
    payload key) and ``prefill_budget`` arms the per-tick prefill token
    budget on both the engine's chunk steps and the scheduler's
    admission fill — non-positive values raise a client
    :class:`RequestError` at the payload, ``ValueError`` here.
    ``tracer`` (a :class:`~repro.serve.telemetry.Tracer`) records every
    replica's request lifecycles and tick phases into ONE trace; each
    replica also exposes a labelled metrics registry regardless
    (``service_prometheus_text`` merges them)."""
    replicas = []
    for i in range(n_replicas):
        eng = ServingEngine(model, params, batch_size=batch_size,
                            max_seq=max_seq, plan=plan, paged=paged,
                            block_size=block_size, num_blocks=num_blocks,
                            prefix_sharing=prefix_sharing,
                            use_kernel=use_kernel, draft_model=draft_model,
                            draft_params=draft_params,
                            speculation=speculation,
                            prefill_chunk=prefill_chunk,
                            prefill_budget=prefill_budget,
                            tracer=tracer)
        sched = Scheduler(eng, policy=policy, max_queue=max_queue,
                          pressure_shed=pressure_shed,
                          prefill_budget=prefill_budget)
        lm = LMReplica(f"{name}/{i}", sched)
        replicas.append(Replica(f"{name}/{i}", lm,
                                backup=(with_backup and i == n_replicas - 1
                                        and n_replicas > 1)))
    svc = Service(name, replicas=replicas, priority=priority,
                  depends_on=depends_on)
    deploy(svc, policy=balancer_policy)
    if supervisor is not None:
        supervisor.add(svc)
    return svc


def service_prometheus_text(svc: Service) -> str:
    """One Prometheus text exposition for the whole service: every
    replica's registry (labelled per replica) merged with the
    balancer's upstream counters (labelled per service) — the scrape
    endpoint a deployment would mount next to the paper's NGINX
    front door."""
    regs = [r.handler.registry for r in svc.replicas
            if hasattr(r.handler, "registry")]
    bal = getattr(svc, "balancer", None)
    if bal is not None and hasattr(bal, "metrics_snapshot"):
        breg = MetricsRegistry(labels={"service": svc.name})
        breg.source("balancer", bal.metrics_snapshot)
        regs.append(breg)
    return prometheus_text(regs)
