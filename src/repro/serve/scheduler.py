"""Request scheduler in front of the ServingEngine: admission queue,
continuous batching, SLO-aware policies, and per-request stats.

The paper's front-end (NGINX + parser PaaS) admits requests at arbitrary
concurrency and the deployment's worker slots queue the excess
(bench_concurrency reproduces that). This module is the LM analogue for
a single model service: requests arrive asynchronously, the scheduler
fills free engine slots by policy, and every decode tick serves all
active slots (continuous batching).

Policies:
    fifo      arrival order
    spf       shortest-prompt-first (reduces head-of-line blocking from
              long prefills)
    priority  highest ``Request.priority`` tier first, FIFO within a tier
    deadline  earliest ``Request.deadline_s`` first (EDF); requests whose
              deadline has already passed are shed at dequeue time rather
              than burning slots on work nobody can use

With ``max_queue`` set, submission is bounded (NGINX worker-queue
semantics: excess requests are rejected, counted in ``stats.rejected``);
``deadline`` additionally rejects at submit time any request that is
already past its deadline.

Paged engines gate admission on **pool blocks**, not just free slots:
the fill loop stops at the first pick the pool cannot hold (in-order, no
bypass — a blocked head is not starved by smaller requests behind it),
and with ``pressure_shed`` set the scheduler sheds queued work when the
engine reports memory pressure at or above the threshold: the backlog is
trimmed — worst-ranked first (lowest priority / latest deadline / back
of the queue) — until its total block demand fits what the pool can
still hold alongside the resident sequences. Slot exhaustion is no
longer the only shedding trigger; memory is.

Block demand is the engine's ``blocks_needed`` — the **post-sharing**
cost when prefix sharing is on (a prompt whose prefix is already
resident only pays for its un-shared suffix, with revived cached-free
blocks and imminent copy-on-writes charged), **plus the speculative
watermark** on a speculating engine: the blocks a request's first
draft-and-verify window will grow into, so a fill batch doesn't pass
the gate and then mass-park on its first speculative step. A queue of
template-sharing requests is neither over-gated nor over-shed. The
never-servable check at submit keeps the worst-case bound
(``blocks_worst_case``): a prefix match may be gone by the time a
preempted request re-admits — and a window the pool cannot grant only
degrades speculation, never serviceability.

With ``prefill_budget`` set, every tick also charges a **prefill token
budget**: the chunk tokens active slots will feed this step (chunked
prompt ingestion mid-flight) are charged first, and new admissions only
join with the remainder — so a burst of long-prompt arrivals is paced
across ticks instead of stacking admission prefills onto one decode
step. The same value caps the engine's per-step chunk tokens across
slots; an idle engine admits regardless (there is no decode latency to
protect, and an over-budget prompt must not livelock).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

from repro.serve.engine import Request, ServingEngine
from repro.serve.telemetry import PID_REQUESTS

POLICIES = ("fifo", "spf", "priority", "deadline")


@dataclass
class SchedulerStats:
    admitted: int = 0
    completed: int = 0
    rejected: int = 0
    shed: int = 0                   # expired deadlines dropped pre-prefill
    ticks: int = 0
    queue_peak: int = 0
    slo_hits: int = 0
    slo_misses: int = 0
    planned_ahead: int = 0          # admission costs precomputed off-tick
    plan_hits: int = 0              # fill() decisions served from the cache
    latencies_s: list = field(default_factory=list)
    queue_wait_s: list = field(default_factory=list)
    completed_by_priority: dict = field(default_factory=dict)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile: the smallest sample such that at
        least ``q`` of the data is <= it (rank ``ceil(q * n)``,
        1-indexed, clamped to [1, n]). The old ``int(q * n)`` index sat
        one past the rank whenever ``q * n`` landed on an integer — p50
        of 10 samples read the 6th, and any q >= (n-1)/n read the max —
        biasing every small-sample percentile high (the bench TTFT-p99
        gates read this)."""
        if not self.latencies_s:
            return 0.0
        xs = sorted(self.latencies_s)
        rank = max(1, min(math.ceil(q * len(xs)), len(xs)))
        return xs[rank - 1]

    def mean_queue_wait_s(self) -> float:
        if not self.queue_wait_s:
            return 0.0
        return sum(self.queue_wait_s) / len(self.queue_wait_s)


class Scheduler:
    """Admission + slot-filling policy over a ServingEngine."""

    def __init__(self, engine: ServingEngine, *, policy: str = "fifo",
                 max_queue: int = 0, pressure_shed: float | None = None,
                 prefill_budget: int | None = None, clock=None):
        assert policy in POLICIES, policy
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError(f"prefill_budget must be >= 1, got "
                             f"{prefill_budget}")
        self.engine = engine
        self.policy = policy
        self.max_queue = max_queue            # 0 = unbounded
        self.pressure_shed = pressure_shed    # occupancy threshold, None=off
        # per-tick cap on prefill tokens (chunk continuation + new
        # admissions); None = unbudgeted
        self.prefill_budget = prefill_budget
        # shares the engine's clock by default so deadlines, queue waits,
        # and engine latency stamps live on one timeline (virtual in
        # tests) — and the engine's tracer, so queue spans land in the
        # same trace as the lifecycle spans the engine emits
        self.clock = clock if clock is not None else engine.clock
        self.tracer = engine.tracer
        self.queue: deque = deque()
        self.stats = SchedulerStats()
        self._enq_t: dict[int, float] = {}
        self.shed_requests: list = []
        # plan-ahead cache: rid -> (pool_version, (need, cost)); entries
        # are only valid while the pool hasn't changed since they were
        # computed (see _pool_version)
        self._plan: dict[int, tuple[int, tuple]] = {}

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> bool:
        if len(req.prompt) > self.engine.max_seq or \
                (self.engine.paged and self.engine.blocks_worst_case(req)
                 > self.engine.pool.total):
            # unservable: would raise from the engine mid-batch at tick
            # time and take its co-dequeued batchmates down with it
            self.stats.rejected += 1
            return False
        if self.max_queue and len(self.queue) >= self.max_queue:
            self.stats.rejected += 1
            return False
        if self.policy == "deadline" and req.deadline_s is not None \
                and req.deadline_s <= self.clock():
            self.stats.rejected += 1
            return False
        self.queue.append(req)
        self._enq_t[req.rid] = self.clock()
        if self.tracer.enabled:
            self.tracer.instant("submit", pid=PID_REQUESTS, tid=req.rid,
                                ts=self._enq_t[req.rid],
                                args={"queue_depth": len(self.queue)})
        self.stats.admitted += 1
        self.stats.queue_peak = max(self.stats.queue_peak, len(self.queue))
        return True

    # ------------------------------------------------------------ policy
    def _next_index(self) -> int:
        if self.policy == "spf":
            return min(range(len(self.queue)),
                       key=lambda i: len(self.queue[i].prompt))
        if self.policy == "priority":
            # max priority; ties resolved FIFO by queue position
            return max(range(len(self.queue)),
                       key=lambda i: (self.queue[i].priority,
                                      -i))
        if self.policy == "deadline":
            inf = float("inf")
            return min(range(len(self.queue)),
                       key=lambda i: (self.queue[i].deadline_s
                                      if self.queue[i].deadline_s is not None
                                      else inf))
        return 0

    def _shed(self, req: Request) -> None:
        req.done_s = self.clock()
        self._enq_t.pop(req.rid, None)
        self._plan.pop(req.rid, None)
        self.stats.shed += 1
        if self.tracer.enabled:
            self.tracer.instant("shed", pid=PID_REQUESTS, tid=req.rid,
                                ts=req.done_s)
        self.shed_requests.append(req)

    def _shed_index(self) -> int:
        """Worst-ranked queued request — the opposite end of the scale
        ``_next_index`` picks from: lowest priority (latest arrival on
        ties), latest deadline (no-SLO requests first), or the back of
        the queue for fifo/spf."""
        if self.policy == "priority":
            return min(range(len(self.queue)),
                       key=lambda i: (self.queue[i].priority, -i))
        if self.policy == "deadline":
            inf = float("inf")
            return max(range(len(self.queue)),
                       key=lambda i: (self.queue[i].deadline_s
                                      if self.queue[i].deadline_s is not None
                                      else inf))
        return len(self.queue) - 1

    def _shed_for_memory_pressure(self) -> None:
        """When pool occupancy crosses ``pressure_shed``, bound the
        backlog to what the KV pool can still hold next to the resident
        sequences: shed worst-ranked queued requests until the queue's
        total block demand fits the free pool. Fires on *memory*
        pressure — a paged engine can have free slots and still be out
        of KV blocks."""
        avail = self.engine.blocks_available()
        if avail is None:                       # fixed-stripe: slots gate
            return
        demand = sum(self.engine.blocks_needed(r) for r in self.queue)
        while self.queue and demand > avail:
            i = self._shed_index()
            req = self.queue[i]
            del self.queue[i]
            demand -= self.engine.blocks_needed(req)
            self._shed(req)

    # --------------------------------------------------------- plan-ahead
    def _pool_version(self) -> int:
        """Validity stamp for cached admission costs. Only a
        prefix-sharing engine's costs depend on pool state (the
        prefix-match walk reads the index, which ``pool.version`` bumps
        on every mutation); stripe engines and non-sharing paged
        engines price an admission as a pure function of the request,
        so a constant stamp never invalidates — a decode-step alloc or
        a retire's free must not flush plans it cannot have changed."""
        if self.engine.paged and self.engine.prefix_sharing:
            return self.engine.pool.version
        return 0

    def plan_ahead(self, limit: int = 32) -> int:
        """Precompute admission costs for up to ``limit`` queued
        candidates so the next ``fill()`` finds them cached. This is the
        host work the async serve loop hides behind the in-flight device
        step (dispatch → **plan** → commit): it only *reads* engine and
        pool state, so it is safe between dispatch and commit. Returns
        the number of requests planned."""
        v = self._pool_version()
        n = 0
        for req in list(self.queue)[:limit]:
            hit = self._plan.get(req.rid)
            if hit is not None and hit[0] == v:
                continue
            self._plan[req.rid] = (v, self.engine.admission_costs(req))
            n += 1
        self.stats.planned_ahead += n
        return n

    def _planned_costs(self, req: Request) -> tuple:
        """(need, cost) for admitting ``req`` — from the plan-ahead cache
        when still valid, else one fresh prefix-match walk."""
        hit = self._plan.pop(req.rid, None)
        if hit is not None and hit[0] == self._pool_version():
            self.stats.plan_hits += 1
            if self.tracer.enabled:
                self.tracer.instant("plan_hit", pid=PID_REQUESTS,
                                    tid=req.rid)
            return hit[1]
        if self.tracer.enabled:
            self.tracer.instant("plan_miss", pid=PID_REQUESTS,
                                tid=req.rid,
                                args={"stale": hit is not None})
        return self.engine.admission_costs(req)

    # ------------------------------------------------------------- cancel
    def cancel(self, rid: int) -> bool:
        """Abandon a request wherever it lives: still queued (removed,
        nothing was computed) or mid-flight in the engine (slot retired,
        KV blocks freed). Returns False if the rid is unknown — e.g.
        already finished. Must not be called between the engine's
        ``dispatch_step`` and ``commit``."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                req.done_s = self.clock()
                self._enq_t.pop(rid, None)
                self._plan.pop(rid, None)
                return True
        return self.engine.cancel(rid)

    # ------------------------------------------------------------ serving
    def fill(self) -> None:
        """Admission half of a tick: shed on memory pressure, then fill
        free engine slots from the queue (one batched prefill, bounded
        by pool blocks and the per-tick prefill token budget)."""
        if self.pressure_shed is not None and self.queue \
                and self.engine.memory_pressure() >= self.pressure_shed:
            self._shed_for_memory_pressure()
        batch, planned_blocks = [], 0
        budget = None
        if self.prefill_budget is not None:
            # chunk continuation is charged FIRST: slots mid-prompt keep
            # their per-tick token share; new prefills only join with
            # what's left, so a burst of long arrivals cannot starve the
            # decode tick with admission prefill work
            budget = self.prefill_budget \
                - self.engine.pending_chunk_tokens()
        while self.queue and len(batch) < len(self.engine.free_slots()):
            i = self._next_index()
            req = self.queue[i]
            if self.policy == "deadline" and req.deadline_s is not None \
                    and req.deadline_s <= self.clock():
                del self.queue[i]
                self._shed(req)
                continue
            # one prefix-match walk per candidate answers both gates
            # (or zero walks, when plan_ahead() already did it)
            need, cost = self._planned_costs(req)
            if not self.engine.can_admit(req, planned_blocks, need=need):
                break               # pool full: head waits for block frees
            if budget is not None:
                if cost > budget and (batch or self.engine.active):
                    break           # head waits for a tick with room —
                    #                 unless the engine is idle (nothing
                    #                 to protect, and waiting would
                    #                 livelock an over-budget prompt)
                budget -= cost
            del self.queue[i]
            planned_blocks += need
            batch.append(req)
        if batch or self.engine.waiting:
            # even with an empty batch the engine must get a chance to
            # re-admit its preempted requests, or they'd wait forever
            # once the scheduler queue drains
            admitted = self.engine.add_requests(batch)
            # blocks may have gone to engine-internal re-admissions
            # (preempted requests resume first): requeue the remainder
            for req in reversed(batch[admitted:]):
                self.queue.appendleft(req)
            now = self.clock()
            for req in batch[:admitted]:
                t_enq = self._enq_t.pop(req.rid)
                self.stats.queue_wait_s.append(now - t_enq)
                if self.tracer.enabled:
                    # same endpoints as the queue_wait_s stat, so the
                    # trace's queued span IS the reported queue wait
                    self.tracer.complete("queued", t_enq, now - t_enq,
                                         pid=PID_REQUESTS, tid=req.rid)

    def account(self, done: list) -> list:
        """Stats half of a tick: latency/SLO bookkeeping for the finished
        requests one engine step returned."""
        self.stats.ticks += 1
        for r in done:
            self.stats.completed += 1
            self.stats.latencies_s.append(r.latency_s)
            tier = self.stats.completed_by_priority
            tier[r.priority] = tier.get(r.priority, 0) + 1
            if r.deadline_s is not None:
                if r.done_s <= r.deadline_s:
                    self.stats.slo_hits += 1
                else:
                    self.stats.slo_misses += 1
        return done

    def tick(self) -> list:
        """Fill free slots, run one decode step, account the finishers.
        Returns finished requests. The async serve loop runs the same
        three phases but slips plan-ahead work between the engine's
        dispatch and commit."""
        self.fill()
        return self.account(self.engine.step())

    def drain(self) -> list:
        """Run until queue and engine (slots + preempted backlog) empty."""
        out = []
        while self.queue or self.engine.active or self.engine.waiting \
                or self.engine._finished_at_admit:
            out.extend(self.tick())
        return out
