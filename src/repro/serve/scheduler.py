"""Request scheduler in front of the ServingEngine: admission queue,
continuous batching, and per-request SLO tracking.

The paper's front-end (NGINX + parser PaaS) admits requests at arbitrary
concurrency and the deployment's worker slots queue the excess
(bench_concurrency reproduces that). This module is the LM analogue for
a single model service: requests arrive asynchronously, the scheduler
fills free engine slots in arrival order (FIFO) or shortest-prompt-first
(SPF — reduces head-of-line blocking from long prefills), and every
decode tick serves all active slots (continuous batching).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

from repro.serve.engine import Request, ServingEngine


@dataclass
class SchedulerStats:
    admitted: int = 0
    completed: int = 0
    rejected: int = 0
    ticks: int = 0
    queue_peak: int = 0
    latencies_s: list = field(default_factory=list)
    queue_wait_s: list = field(default_factory=list)

    def percentile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        xs = sorted(self.latencies_s)
        return xs[min(int(q * len(xs)), len(xs) - 1)]


class Scheduler:
    """Admission + slot-filling policy over a ServingEngine."""

    def __init__(self, engine: ServingEngine, *, policy: str = "fifo",
                 max_queue: int = 0):
        assert policy in ("fifo", "spf")
        self.engine = engine
        self.policy = policy
        self.max_queue = max_queue            # 0 = unbounded
        self.queue: deque = deque()
        self.stats = SchedulerStats()
        self._enq_t: dict[int, float] = {}

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> bool:
        if self.max_queue and len(self.queue) >= self.max_queue:
            self.stats.rejected += 1
            return False
        self.queue.append(req)
        self._enq_t[req.rid] = time.perf_counter()
        self.stats.admitted += 1
        self.stats.queue_peak = max(self.stats.queue_peak, len(self.queue))
        return True

    def _next_index(self) -> int:
        if self.policy == "spf":
            return min(range(len(self.queue)),
                       key=lambda i: len(self.queue[i].prompt))
        return 0

    # ------------------------------------------------------------ serving
    def tick(self) -> list:
        """Fill free slots, run one decode step. Returns finished reqs."""
        while self.queue:
            i = self._next_index()
            req = self.queue[i]
            if not self.engine.add_request(req):
                break                          # engine full
            del self.queue[i]
            self.stats.queue_wait_s.append(
                time.perf_counter() - self._enq_t.pop(req.rid))
        done = self.engine.step()
        self.stats.ticks += 1
        for r in done:
            self.stats.completed += 1
            self.stats.latencies_s.append(r.latency_s)
        return done

    def drain(self) -> list:
        """Run until queue and engine are empty."""
        out = []
        while self.queue or any(r is not None for r in self.engine.slot_req):
            out.extend(self.tick())
        return out
