"""DraftRunner: the small proposer model inside a speculative engine.

One runner owns the draft model's decode state for every engine slot —
a **fixed-stripe** cache (``draft_model.init_cache(B, max_seq)``): the
draft is small by construction, so a max_seq stripe per slot is cheap,
and stripe rollback is free (truncate the valid length; junk past it is
never attended and is overwritten by the next write at that position,
the same invariant the target engine already proves for mixed-length
decode). The target's paged pool needs real block rollback; the draft
does not.

Per speculative round the runner ingests, batched across slots, each
proposing row's **catch-up tokens** (committed tokens the draft has not
cached yet — usually the previous round's bonus/correction token plus
the last proposal when everything was accepted, but arbitrarily many
after the target ran chunk-prefill ticks without the draft) in ONE
chunked-prefill window call (``model.prefill`` chunk mode — the serial
token-per-step catch-up loop it replaced cost ``max(catch) - 1`` draft
steps), then runs exactly ``k`` **proposal** draws. Rows not proposing
this round ride the batch with their writes landing harmlessly past
their own valid stripe extent. Proposals are drawn with the *request's*
sampling params (greedy rows propose the draft argmax) from a dedicated
key stream, and every proposal's shaped distribution is returned for
acceptance sampling.

The engine owns commit/rollback: after acceptance it calls
:meth:`commit` with the new valid draft length (cached committed
prefix), and :meth:`reset` when a slot retires or is preempted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import sampling
from repro.serve.telemetry import NOOP, PID_LOOP

_MIN_BUCKET = 8     # matches the engine's smallest prefill bucket


class DraftRunner:
    def __init__(self, model, params, *, batch_size: int, max_seq: int,
                 plan=None, tracer=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.plan = plan
        # shared with the owning engine: proposal rounds land on the
        # same serve-loop trace track as the tick phases
        self.tracer = NOOP if tracer is None else tracer
        cache_spec = jax.eval_shape(lambda: model.init_cache(1, _MIN_BUCKET))
        if not set(cache_spec) <= {"k", "v"}:
            # the runner's whole rollback story is stripe semantics:
            # rejected proposals leave junk KV past the valid length,
            # truncating `len` rewinds. Recurrent state (rwkv / hybrid
            # SSM) has no positions to truncate — rejected proposals
            # would corrupt it irreversibly and acceptance would decay
            # to zero, silently turning speculation into pure overhead.
            raise ValueError("draft model must have a pure-attention "
                             "{k, v} cache (rollback is truncate-only); "
                             f"got leaves {sorted(cache_spec)}")
        self.caches = model.init_cache(batch_size, max_seq)
        self.len = np.zeros(batch_size, np.int32)   # valid cached tokens
        self.steps_run = 0                          # draft decode steps

        def admit(p, caches, tokens, last_idx, slots):
            """Batched draft prefill + stripe insertion (device-side,
            caches donated) — the engine admit path minus the sampled
            first token: the draft never emits, it only caches."""
            _, pref = model.prefill(p, {"tokens": tokens}, plan,
                                    last_idx=last_idx)
            for j in range(tokens.shape[0]):
                for key in caches:
                    row = jax.lax.dynamic_slice_in_dim(pref[key], j, 1,
                                                       axis=1)
                    start = (jnp.int32(0), slots[j]) + \
                        (jnp.int32(0),) * (row.ndim - 2)
                    caches[key] = jax.lax.dynamic_update_slice(
                        caches[key], row.astype(caches[key].dtype), start)
            return caches

        def step(p, tok, caches, lengths, temps, top_ks, seeds, ctrs, pos):
            """One draft decode step: returns (proposal (B,), shaped
            proposal probs (B, V) f32, caches)."""
            logits, caches = model.decode_step(p, tok, caches, lengths,
                                               plan)
            nxt, probs = sampling.draft_propose(logits[:, -1, :], temps,
                                                top_ks, seeds, ctrs, pos)
            return nxt, probs, caches

        def ingest(p, toks, caches, lengths):
            """Chunked catch-up: write each row's uncached committed
            tokens into its stripe in one multi-token window (positions
            ``lengths[b] + [0, S)``; pad rows' junk lands past their
            valid extent). Logits discarded — the draft only needs the
            cache, so only position 0 is projected (last_idx=0)."""
            _, caches = model.prefill(p, {"tokens": toks}, plan,
                                      cache=caches, cache_len=lengths,
                                      last_idx=jnp.zeros(toks.shape[0],
                                                         jnp.int32))
            return caches

        self._admit = jax.jit(admit, donate_argnums=(1,))
        self._step = jax.jit(step, donate_argnums=(2,))
        self._ingest = jax.jit(ingest, donate_argnums=(2,))

    # --------------------------------------------------------- admission
    def admit(self, members: list) -> None:
        """Prefill the draft cache for freshly admitted slots.
        ``members``: list of (slot, prompt tokens). Prompts are grouped
        by power-of-two bucket (a {k, v} cache tolerates right-padding;
        an MoE draft's pad perturbation only nudges *proposals*, never
        target correctness) and each group prefills as one batched
        call."""
        # the ENGINE's bucket rule, lazily imported (engine imports this
        # module at load time): draft prefill shapes must track target
        # prefill shapes so a policy change never diverges the two
        from repro.serve.engine import _bucket
        groups: dict = {}
        for slot, eff in members:
            key = _bucket(len(eff), self.max_seq)
            groups.setdefault(key, []).append((slot, eff))
        for width, group in groups.items():
            toks = np.zeros((len(group), width), np.int32)
            last = np.zeros(len(group), np.int32)
            slots = np.zeros(len(group), np.int32)
            for j, (slot, eff) in enumerate(group):
                toks[j, :len(eff)] = eff
                last[j] = len(eff) - 1
                slots[j] = slot
            self.caches = self._admit(self.params, self.caches,
                                      jnp.asarray(toks), jnp.asarray(last),
                                      jnp.asarray(slots))
        for slot, eff in members:
            self.len[slot] = len(eff)

    # --------------------------------------------------------- proposals
    def propose(self, tails: list, rows: list, k: int, temps, top_ks,
                seeds, ctrs):
        """Catch-up + propose ``k`` tokens for each slot in ``rows``.

        tails[i]: the committed tokens slot i's draft cache has NOT seen
        yet, ending with the newest committed token (never empty for a
        proposing row; None for the rest — the engine hands over only
        the uncached suffix, so this is O(catch), not O(context)).
        Returns (proposed (B, k) int32 host array, draft_probs
        (B, k, V) device array — the shaped distribution each proposal
        was drawn from).

        All catch-up except each row's last token lands in ONE chunked
        ingest call, so a round costs ``1 + k`` draft steps however far
        the draft fell behind (the serial loop cost
        ``max(catch) - 1 + k``); the last catch-up token then draws the
        first proposal, aligning every row at the same loop offset.
        """
        B, L = self.B, self.len
        catch = np.ones(B, np.int64)
        for i in rows:
            catch[i] = len(tails[i])
            assert catch[i] >= 1, (i, int(L[i]))
        pre = int(max(catch[i] for i in rows)) - 1
        if pre > 0:
            from repro.serve.engine import _bucket   # lazy: engine imports us
            W = _bucket(pre, self.max_seq)
            toks = np.zeros((B, W), np.int32)
            for i in rows:
                toks[i, :catch[i] - 1] = tails[i][:-1]
            self.caches = self._ingest(self.params, jnp.asarray(toks),
                                       self.caches,
                                       jnp.asarray(L.astype(np.int32)))
            for i in rows:
                L[i] += catch[i] - 1        # caches valid through the ingest
            self.steps_run += 1
        proposed = np.zeros((B, k), np.int32)
        probs_steps = []
        tok = np.zeros((B, 1), np.int32)
        last = np.zeros(B, np.int32)
        for t in range(k):
            for i in rows:
                # the last catch-up token draws the first proposal
                tok[i, 0] = tails[i][-1] if t == 0 else last[i]
            nxt, probs, self.caches = self._step(
                self.params, jnp.asarray(tok), self.caches,
                jnp.asarray((L + t).astype(np.int32)), temps, top_ks,
                seeds, ctrs, jnp.asarray(np.full(B, t, np.int32)))
            probs_steps.append(probs)
            nxt = np.asarray(nxt)
            for i in rows:
                proposed[i, t] = nxt[i]
                last[i] = nxt[i]
        self.steps_run += k
        if self.tracer.enabled:
            self.tracer.instant("draft_propose", pid=PID_LOOP,
                                args={"rows": len(rows), "k": k,
                                      "catchup_tokens": int(pre)})
        draft_probs = jnp.stack(probs_steps, axis=1)        # (B, k, V)
        return proposed, draft_probs

    # ------------------------------------------------------- bookkeeping
    def commit(self, slot: int, valid_len: int) -> None:
        """Acceptance result for ``slot``: the draft's cache is valid
        through ``valid_len`` committed tokens (everything past it is a
        rejected proposal's KV — stripe junk, overwritten by the next
        catch-up write at that position)."""
        self.len[slot] = min(valid_len, self.max_seq)

    def reset(self, slot: int) -> None:
        self.len[slot] = 0
