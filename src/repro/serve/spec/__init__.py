"""Speculative multi-token decode: draft-and-verify serving.

Two models cooperate inside one engine step — the paper's
parallel-models story made hardware-efficient. A small **draft** model
proposes ``k`` tokens per slot (k cheap sequential steps on a tiny
model); the **target** model verifies all proposals in ONE batched
multi-token step (``Model.verify_step``, q_len = k+1 with causal
masking inside the window) and commits the accepted prefix plus a
bonus/correction token, so every target step can emit *several* tokens.

* :class:`~repro.serve.spec.draft.DraftRunner` — owns the draft
  model's slot-parallel KV stripes, batched prompt prefill, and the
  catch-up + proposal loop.
* Acceptance lives in :mod:`repro.serve.sampling`
  (``speculative_accept``): greedy exact-match (deterministic — streams
  bit-identical to non-speculative greedy decode) or acceptance
  sampling against the draft's proposal distributions.
* The paged-KV **watermark/rollback** protocol lives in the engine:
  blocks for the speculative window are granted (copy-on-write where
  shared) *before* the verify step, and blocks past the accepted
  length are returned to the pool after it.

See docs/serving.md ("Speculative decode") for the full protocol.
"""
from repro.serve.spec.draft import DraftRunner

__all__ = ["DraftRunner"]
