"""Serving engine: KV-cache lifecycle + batched prefill/decode for one
model, and a request scheduler that batches concurrent requests (the
substrate under every PaaS replica when the payload is an LM).

The engine slots requests into a fixed-capacity batch (contiguous KV
cache, one slot per sequence), prefills new requests, then decodes all
active slots in lock-step — continuous-batching-lite, matching the
paper's near-real-time serving target rather than max-throughput.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: list                    # token ids
    max_new_tokens: int = 8
    out_tokens: list = field(default_factory=list)
    submitted_s: float = field(default_factory=time.perf_counter)
    done_s: float | None = None

    @property
    def latency_s(self) -> float:
        return (self.done_s or time.perf_counter()) - self.submitted_s


class ServingEngine:
    def __init__(self, model, params, *, batch_size: int = 4,
                 max_seq: int = 256, plan=None, greedy: bool = True):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.plan = plan
        cfg = model.cfg
        self.caches = model.init_cache(batch_size, max_seq)
        self.slot_len = np.zeros(batch_size, np.int32)   # tokens in cache
        self.slot_req: list = [None] * batch_size
        # jitted single-slot prefill (B=1) and batched decode
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, plan))
        self._decode = jax.jit(
            lambda p, t, c, l: model.decode_step(p, t, c, l, plan))
        self.metrics = {"prefills": 0, "decode_steps": 0, "completed": 0}

    # ------------------------------------------------------------- slots
    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot; False if engine is full."""
        slot = self._free_slot()
        if slot is None:
            return False
        P = len(req.prompt)
        toks = jnp.asarray([req.prompt], jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": toks})
        # write the prefill cache into the slot (host-side copy; fine at
        # example scale — the dry-run path never goes through here)
        for key in self.caches:
            c = np.array(self.caches[key])          # writable host copy
            pref = np.asarray(cache[key])
            if c.ndim >= 3 and pref.ndim == c.ndim and \
                    c.shape[2] == self.max_seq and pref.shape[2] <= self.max_seq:
                c[:, slot] = 0
                c[:, slot, :pref.shape[2]] = pref[:, 0]
            else:  # state-style caches (L, B, ...)
                c[:, slot] = pref[:, 0]
            self.caches[key] = jnp.asarray(c)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.out_tokens.append(nxt)
        self.slot_req[slot] = req
        self.slot_len[slot] = P
        self.metrics["prefills"] += 1
        return True

    # ------------------------------------------------------------- decode
    def step(self) -> list:
        """One lock-step decode over all active slots. Returns finished
        requests."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return []
        if len(set(self.slot_len[i] for i in active)) == 1:
            cache_len = jnp.int32(int(self.slot_len[active[0]]))
        else:
            # lock-step engine: pad to the longest (masking handles shorter)
            cache_len = jnp.int32(int(max(self.slot_len[i] for i in active)))
        tok = np.zeros((self.B, 1), np.int32)
        for i in active:
            tok[i, 0] = self.slot_req[i].out_tokens[-1]
        logits, self.caches = self._decode(self.params, jnp.asarray(tok),
                                           self.caches, cache_len)
        self.metrics["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1))
        finished = []
        for i in active:
            r = self.slot_req[i]
            r.out_tokens.append(int(nxt[i]))
            self.slot_len[i] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done_s = time.perf_counter()
                finished.append(r)
                self.slot_req[i] = None
                self.slot_len[i] = 0
                self.metrics["completed"] += 1
        return finished

    # ------------------------------------------------------------- run
    def run(self, requests: list) -> list:
        """Serve a list of requests to completion (batched)."""
        pending = list(requests)
        done: list = []
        while pending or any(r is not None for r in self.slot_req):
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            done.extend(self.step())
        return done
