"""Slot-native serving engine: paged block-pool KV cache, batched
prefill admission, and mixed-length continuous-batching decode for one
model (the substrate under every PaaS replica when the payload is an LM).

The engine slots requests into a fixed-capacity batch (one slot per
sequence). KV memory comes in two layouts:

* **Paged (default for pure-attention caches, leaves ``{k, v}``)** — a
  shared :class:`~repro.serve.blocks.BlockPool` of ``num_blocks x
  block_size`` tokens per layer. A slot holds only the blocks its
  sequence actually needs (``ceil(len / block_size)``), mapped through a
  per-slot block table; admission is gated on *blocks*, not on a free
  ``max_seq`` stripe, so many short requests fit where few stripes did.
  Decode grows a slot's table lazily as it crosses block boundaries;
  on exhaustion the slot **parks** (skips token emission, state intact)
  until another request frees blocks — and if every active slot is
  parked, the newest admission is **preempted** (blocks freed, request
  re-queued for recompute re-admission) so the oldest can advance.
* **Fixed-stripe (recurrent rwkv / hybrid-SSM / cross-attn caches)** —
  one ``max_seq`` stripe per slot at ``model.init_cache(B, max_seq)``.
  Recurrent state is O(1) in sequence length, so paging buys nothing
  there; the stripe path is also the reference the paged path must
  match token-for-token.

Paged engines add two behaviours on top of the block tables:

* **Prefix sharing + copy-on-write** (``prefix_sharing=True``, non-MoE):
  admission walks the prompt through the pool's prefix index and
  *acquires* blocks already holding that content instead of recomputing
  and re-storing them — the request prefills only its un-shared suffix
  (fed through ordinary decode steps), and the scheduler's block gate
  charges only that post-sharing cost. A shared block is read-only;
  the first append into a shared tail duplicates it on device first
  (copy-on-write), so no holder ever sees another's tokens.
* **In-place kernel decode** (``use_kernel=True``): the paged attention
  read runs the Pallas kernel in ``kernels/paged_attention`` (K/V read
  through the block table via scalar-prefetched index maps, no
  transient gather; interpret mode off-TPU) instead of the jnp gather
  reference.

With ``speculation=k`` (and a draft model) the engine decodes
**speculatively**: each step, a :class:`~repro.serve.spec.DraftRunner`
proposes k tokens per slot and the target verifies them in ONE
multi-token step (``model.verify_step``), committing the accepted
prefix plus a bonus/correction token — up to k+1 tokens per slot per
target step. Paged slots are granted their window blocks up front (the
**watermark**; copy-on-write where shared, degraded under pressure)
and rolled back to the committed length afterwards; greedy acceptance
is deterministic and the streams are bit-identical to non-speculative
decode (docs/serving.md, "Speculative decode"). Every emitted token is
drawn by the per-request sampler (``serve/sampling.py``: greedy /
temperature / top-k, counter-based keys) and streams with its logprob.

Three properties carry over from the stripe engine and hold in both
layouts:

* **Device-side admission** — prefill writes the new sequence's KV into
  its slot (stripe) or its blocks (pool) with jitted
  ``jax.lax.dynamic_update_slice`` (cache buffers donated); the full
  cache never round-trips through host numpy. Several waiting requests
  prefill as one batch.
* **Mixed-length decode** — every slot keeps its own length; one decode
  step ropes, writes, and masks each row at its own position, so slots
  at different depths decode together bit-exactly for dense/recurrent
  families. MoE is the one caveat (capacity routing shares per-expert
  budget across co-batched rows — see docs/serving.md, "The MoE
  caveat"), and the reason MoE admission prefills one row at a time.
* **Slot recycling mid-flight** — EOS/stop-token early exit frees a slot
  (and its blocks) the moment its request finishes; the next waiting
  request is admitted into it while the other slots keep decoding.

Prompts for paddable caches are right-padded to power-of-two buckets so
admission compiles O(B x log max_seq) variants, not one per prompt
length; pad positions are never attended (per-slot length masks them)
and pad tail blocks are never allocated — a paged slot pays blocks for
its *real* tokens only.

**Chunked prefill** (``prefill_chunk``, default on for paddable
families): a prompt longer than the chunk admits with its FIRST chunk
only; the remainder becomes the slot's pending queue and feeds through
**chunk windows** — multi-token steps (the verify machinery) that write
each row's next ``<= chunk`` prompt tokens at its own positions while
every decode slot rides the same batch with its single next token. A
long prompt therefore admits as a sequence of budgeted chunk steps
interleaved with decode instead of one monolithic stall — the
head-of-line blocking fix the paper's sub-700ms responsiveness claim
needs under sequential long-document arrival. The same queue drains a
shared admission's un-shared suffix chunk-at-a-time, which removes the
old bounded-suffix trade on prefix sharing (the suffix used to feed one
token per step, so only short suffixes could share); chunk-written
prompt blocks register in the prefix index exactly as prefilled ones
do, so half-prefilled prompts share forward too. Chunked streams are
bit-identical to monolithic prefill (``tests/test_chunked.py`` holds
the whole engine grid to it). ``prefill_chunk=0`` restores monolithic
admission; recurrent and MoE families always prefill monolithically
(multi-token windows need the ``{k, v}`` scatter and bit-exact
co-batching).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import sampling
from repro.serve.blocks import BlockPool
from repro.serve.sampling import GREEDY, SamplingParams
from repro.serve.spec import DraftRunner
from repro.serve.telemetry import NOOP, PID_LOOP, PID_POOL, PID_REQUESTS

_MIN_BUCKET = 8
# default chunk for chunked prefill (tokens per slot per chunk step):
# small enough that a max_seq-sized prompt never stalls decode for more
# than one chunk's compute, large enough that short prompts (the common
# case) still admit in one piece exactly as before
DEFAULT_PREFILL_CHUNK = 64


@dataclass
class Request:
    rid: int
    prompt: list                    # token ids
    max_new_tokens: int = 8
    stop_tokens: tuple = ()         # EOS ids -> early exit
    priority: int = 0               # scheduler tier (higher = more urgent)
    deadline_s: float | None = None  # absolute perf_counter SLO deadline
    sampling: SamplingParams = GREEDY   # greedy | temperature | top-k
    speculation: int | None = None  # draft tokens/step; None = engine
    #                                 default, 0 = opt out of speculation
    prefill_chunk: int | None = None  # per-request chunk width override
    #                                 (None = engine default)
    out_tokens: list = field(default_factory=list)
    out_logprobs: list = field(default_factory=list)  # raw log-softmax of
    #                                 each emitted token, 1:1 with out_tokens
    submitted_s: float = field(default_factory=time.perf_counter)
    done_s: float | None = None
    preemptions: int = 0            # times evicted for recompute readmission
    admitted_s: float | None = None     # first engine-slot admission
    first_token_s: float | None = None  # first *generated* token commit
    #                                 (TTFT = first_token_s - submitted_s)

    @property
    def latency_s(self) -> float:
        return (self.done_s or time.perf_counter()) - self.submitted_s

    @property
    def finished_by_stop(self) -> bool:
        return bool(self.out_tokens) and self.out_tokens[-1] in self.stop_tokens


def _bucket(n: int, cap: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return min(b, cap)


class _Tick:
    """One **dispatched** engine step: the device work is already in
    flight (JAX async dispatch returns before the computation finishes),
    the host-side bookkeeping is deferred to :meth:`commit`. Between
    ``dispatch_step()`` and ``commit()`` the engine's host state must be
    treated as read-only — that window is exactly where the async serve
    loop overlaps next-tick planning (admission cost walks, intake
    validation) with the device step. Commit is one-shot."""

    __slots__ = ("_commit",)

    def __init__(self, commit_fn):
        self._commit = commit_fn

    def commit(self) -> list:
        """Synchronize on the device results, run the per-slot
        bookkeeping, and return the finished requests."""
        fn, self._commit = self._commit, None
        if fn is None:
            raise RuntimeError("tick already committed")
        return fn()


class ServingEngine:
    def __init__(self, model, params, *, batch_size: int = 4,
                 max_seq: int = 256, plan=None, paged: bool | None = None,
                 block_size: int = 16, num_blocks: int | None = None,
                 reserve_blocks: int = 1, prefix_sharing: bool = True,
                 use_kernel: bool = False, draft_model=None,
                 draft_params=None, speculation: int = 0,
                 prefill_chunk: int | None = None,
                 prefill_budget: int | None = None,
                 clock=time.perf_counter, tracer=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.plan = plan
        # injectable time source (completion stamps); a VirtualClock
        # here makes every latency/deadline observable deterministic
        self.clock = clock
        # span/event recorder (serve/telemetry.py). The NOOP default
        # keeps the hot path flat, and every emission site additionally
        # guards on ``.enabled`` so an untraced engine never builds
        # event payloads. Pass a Tracer sharing this clock for traces
        # on the same timeline as the latency stamps.
        self.tracer = NOOP if tracer is None else tracer
        cache_spec = jax.eval_shape(lambda: model.init_cache(1, _MIN_BUCKET))
        pure_attn = set(cache_spec) <= {"k", "v"}
        # MoE routing flattens the whole (rows x tokens) block into one
        # shared per-expert capacity, so pad tokens / co-batched rows can
        # displace real tokens from dispatch — prefill those one row at a
        # time, exact length, to keep admission bit-exact with solo serving.
        is_moe = bool(getattr(model.cfg, "n_experts", 0))
        # pure-attention caches tolerate right-padded prompts (pad KV is
        # masked, then overwritten); recurrent state does not.
        self._paddable = pure_attn and not is_moe
        self._solo_prefill = is_moe
        # recurrent / cross-attn state is O(1) in sequence length: paging
        # buys nothing, keep the stripe layout there.
        self.paged = pure_attn if paged is None else paged
        if self.paged and not pure_attn:
            raise ValueError("paged KV requires a pure-attention {k, v} "
                             f"cache; got leaves {sorted(cache_spec)}")
        # prefix sharing rides on the block tables; the catch-up tokens of
        # a shared admission decode co-batched, which is bit-exact for
        # dense/GQA but not for MoE (the shared expert-capacity caveat
        # again) — so MoE engines never share.
        self.prefix_sharing = bool(prefix_sharing) and self.paged \
            and not is_moe
        self.use_kernel = bool(use_kernel)
        # chunked prefill: prompts longer than the chunk admit with their
        # first chunk and feed the rest through decode-interleaved chunk
        # windows. Needs the multi-token {k, v} window (recurrent state
        # steps token-at-a-time) and bit-exact co-batching (the MoE
        # shared-capacity caveat), so only paddable families chunk;
        # 0 = monolithic admission (the legacy comparison mode).
        if prefill_chunk is not None and prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got "
                             f"{prefill_chunk}")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError(f"prefill_budget must be >= 1, got "
                             f"{prefill_budget}")
        if self._paddable:
            self.prefill_chunk = DEFAULT_PREFILL_CHUNK \
                if prefill_chunk is None else int(prefill_chunk)
        else:
            if prefill_chunk:
                raise ValueError("chunked prefill requires a paddable "
                                 "pure-attention non-MoE cache")
            self.prefill_chunk = 0
        # per-step cap on pending prompt tokens fed across slots (the
        # scheduler charges the same budget before admitting new work)
        self.prefill_budget = prefill_budget
        # speculative draft-and-verify: a small draft model proposes k
        # tokens per slot, the target verifies them in one multi-token
        # step. Pure-attention targets only (the verify window needs the
        # {k, v} scatter; recurrent state steps token-at-a-time) and
        # never MoE (the window co-batches k+1 tokens through shared
        # expert capacity — the standard bit-exactness caveat).
        self.spec_k = int(speculation)
        if self.spec_k:
            if draft_model is None or draft_params is None:
                raise ValueError("speculation requires a draft model")
            if not pure_attn:
                raise ValueError("speculation requires a pure-attention "
                                 f"{{k, v}} cache; got {sorted(cache_spec)}")
            if is_moe:
                raise ValueError("speculation unsupported for MoE targets "
                                 "(expert-capacity caveat, docs/serving.md)")
            self.draft = DraftRunner(draft_model, draft_params,
                                     batch_size=batch_size, max_seq=max_seq,
                                     plan=plan, tracer=self.tracer)
        else:
            self.draft = None
        self.slot_len = np.zeros(batch_size, np.int32)   # tokens in cache
        self.slot_req: list = [None] * batch_size
        # prompt tokens a shared admission still owes the model: fed one
        # per decode step (writing K/V at the slot's own position) until
        # the last prompt token's logits produce the first output token
        self.slot_pending: list = [[] for _ in range(batch_size)]
        # prefix-index registration frontier per slot, for chunk-written
        # prompt blocks: slot_reg is the canonical parent block the next
        # registration chains after (pool.ROOT for a fresh chain, False
        # when the chain is broken and registration stops), slot_reg_pos
        # the prompt position indexed so far
        self.slot_reg: list = [False] * batch_size
        self.slot_reg_pos = np.zeros(batch_size, np.int64)
        self._finished_at_admit: list = []
        self._used_slots: set = set()
        self._waiting: deque = deque()       # preempted, awaiting re-admission
        self._admit_order = np.zeros(batch_size, np.int64)
        self._admit_seq = 0

        if self.paged:
            self.block_size = block_size
            self.blocks_per_slot = -(-max_seq // block_size)
            if num_blocks is None:
                # parity default: same token capacity as B fixed stripes
                num_blocks = batch_size * self.blocks_per_slot + 1  # + scratch
            self.pool = BlockPool(num_blocks, block_size,
                                  tracer=self.tracer)
            self.reserve_blocks = min(reserve_blocks, max(self.pool.total - 1,
                                                          0))
            self.caches = model.init_paged_cache(num_blocks, block_size)
            self.block_table = np.zeros((batch_size, self.blocks_per_slot),
                                        np.int32)
            self.slot_blocks: list = [[] for _ in range(batch_size)]
        else:
            self.pool = None
            self.caches = model.init_cache(batch_size, max_seq)

        def admit(p, caches, tokens, last_idx, slots, temps, top_ks,
                  seeds, ctrs):
            """Batched prefill + device-side stripe insertion.

            tokens (k, S) right-padded prompts, last_idx (k,) index of each
            row's final real token, slots (k,) destination slot per row;
            temps/top_ks/seeds/ctrs (k,) per-row sampling params. Returns
            (first generated token per row, its logprob, updated caches).
            """
            logits, pref = model.prefill(p, {"tokens": tokens}, plan,
                                         last_idx=last_idx)
            for j in range(tokens.shape[0]):
                for key in caches:
                    row = jax.lax.dynamic_slice_in_dim(pref[key], j, 1, axis=1)
                    start = (jnp.int32(0), slots[j]) + \
                        (jnp.int32(0),) * (row.ndim - 2)
                    caches[key] = jax.lax.dynamic_update_slice(
                        caches[key], row.astype(caches[key].dtype), start)
            nxt, logp = sampling.sample(logits[:, -1, :], temps, top_ks,
                                        seeds, ctrs)
            return nxt, logp, caches

        def prefill_paged(p, tokens, last_idx, temps, top_ks, seeds, ctrs):
            """Batched prefill for the pool path: returns the first token
            per row (+ logprob) and the prefill KV padded (with zeros,
            never attended) to a block_size multiple so every logical
            block slices full."""
            logits, pref = model.prefill(p, {"tokens": tokens}, plan,
                                         last_idx=last_idx)
            pad = (-tokens.shape[1]) % block_size
            if pad:
                pref = {key: jnp.pad(pref[key],
                                     ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                        for key in pref}
            nxt, logp = sampling.sample(logits[:, -1, :], temps, top_ks,
                                        seeds, ctrs)
            return nxt, logp, pref

        def write_block(caches, pref, row, start, phys):
            """Copy one logical block of row ``row`` of the prefill KV
            (token window [start, start+block_size)) into physical pool
            block ``phys`` — a device-side dynamic_update_slice on the
            donated pool, same no-host-copy property as the stripe path."""
            for key in caches:
                L = pref[key].shape[0]
                chunk = jax.lax.dynamic_slice(
                    pref[key], (jnp.int32(0), row, start, jnp.int32(0),
                                jnp.int32(0)),
                    (L, 1, block_size) + pref[key].shape[3:])
                caches[key] = jax.lax.dynamic_update_slice(
                    caches[key], chunk.astype(caches[key].dtype),
                    (jnp.int32(0), phys) + (jnp.int32(0),) * 3)
            return caches

        def decode(p, tok, caches, lengths, temps, top_ks, seeds, ctrs):
            logits, caches = model.decode_step(p, tok, caches, lengths, plan)
            nxt, logp = sampling.sample(logits[:, -1, :], temps, top_ks,
                                        seeds, ctrs)
            return nxt, logp, caches

        kernel_flag = self.use_kernel

        def decode_paged(p, tok, caches, lengths, table, temps, top_ks,
                         seeds, ctrs):
            logits, caches = model.decode_step(p, tok, caches, lengths, plan,
                                               block_table=table,
                                               paged_kernel=kernel_flag)
            nxt, logp = sampling.sample(logits[:, -1, :], temps, top_ks,
                                        seeds, ctrs)
            return nxt, logp, caches

        def verify(p, toks, caches, lengths, dprobs, proposed, n_spec,
                   temps, top_ks, seeds, ctrs):
            """Stripe verify: one multi-token step + acceptance."""
            logits, caches = model.verify_step(p, toks, caches, lengths,
                                               plan)
            acc = sampling.speculative_accept(logits, dprobs, proposed,
                                              n_spec, temps, top_ks, seeds,
                                              ctrs)
            return (*acc, caches)

        def verify_paged(p, toks, caches, lengths, table, n_write, dprobs,
                         proposed, n_spec, temps, top_ks, seeds, ctrs):
            """Paged verify: the window scatters through the block table
            (diverted to scratch past each row's granted watermark)."""
            logits, caches = model.verify_step(p, toks, caches, lengths,
                                               plan, block_table=table,
                                               paged_kernel=kernel_flag,
                                               n_write=n_write)
            acc = sampling.speculative_accept(logits, dprobs, proposed,
                                              n_spec, temps, top_ks, seeds,
                                              ctrs)
            return (*acc, caches)

        def chunk(p, toks, caches, lengths, last_idx, temps, top_ks,
                  seeds, ctrs):
            """Stripe chunk window: each row feeds its next pending
            prompt tokens (decode riders their single next token, pads
            past each row's count) through one multi-token window, and
            samples at its own last real position (``last_idx`` — the
            model projects only that position against the vocabulary);
            the draw only counts for rows that finished their prompt
            this window."""
            logits, caches = model.prefill(p, {"tokens": toks}, plan,
                                           cache=caches, cache_len=lengths,
                                           last_idx=last_idx)
            nxt, logp = sampling.sample(logits[:, 0, :], temps, top_ks,
                                        seeds, ctrs)
            return nxt, logp, caches

        def chunk_paged(p, toks, caches, lengths, table, n_write,
                        last_idx, temps, top_ks, seeds, ctrs):
            """Paged chunk window: scatter through the block table,
            diverted to scratch past each row's fed count (pads, parked
            riders)."""
            logits, caches = model.prefill(p, {"tokens": toks}, plan,
                                           cache=caches, cache_len=lengths,
                                           block_table=table,
                                           paged_kernel=kernel_flag,
                                           n_write=n_write,
                                           last_idx=last_idx)
            nxt, logp = sampling.sample(logits[:, 0, :], temps, top_ks,
                                        seeds, ctrs)
            return nxt, logp, caches

        def copy_block(caches, src, dst):
            """Copy-on-write: duplicate physical block ``src`` into the
            freshly-allocated ``dst`` on device (all layers, one jitted
            dynamic_update_slice per leaf, pool donated)."""
            for key in caches:
                nd = caches[key].ndim
                sizes = (caches[key].shape[0], 1) + caches[key].shape[2:]
                blk = jax.lax.dynamic_slice(
                    caches[key], (jnp.int32(0), src) + (jnp.int32(0),)
                    * (nd - 2), sizes)
                caches[key] = jax.lax.dynamic_update_slice(
                    caches[key], blk,
                    (jnp.int32(0), dst) + (jnp.int32(0),) * (nd - 2))
            return caches

        self._admit = jax.jit(admit, donate_argnums=(1,))
        self._prefill_paged = jax.jit(prefill_paged)
        self._write_block = jax.jit(write_block, donate_argnums=(0,))
        self._copy_block = jax.jit(copy_block, donate_argnums=(0,))
        self._decode = jax.jit(decode_paged if self.paged else decode,
                               donate_argnums=(2,))
        self._verify = jax.jit(verify_paged if self.paged else verify,
                               donate_argnums=(2,))
        self._chunk_fn = jax.jit(chunk_paged if self.paged else chunk,
                                 donate_argnums=(2,))
        self.metrics = {"prefills": 0, "prefill_batches": 0,
                        "decode_steps": 0, "completed": 0,
                        "stop_token_exits": 0, "slot_reuses": 0,
                        "blocks_grown": 0, "parked_slot_steps": 0,
                        "preemptions": 0, "shared_admissions": 0,
                        "cow_copies": 0, "cow_parks": 0,
                        "prefill_tokens_computed": 0,
                        "prefill_tokens_shared": 0,
                        "verify_steps": 0, "draft_steps": 0,
                        "spec_proposed": 0, "spec_accepted": 0,
                        "spec_blocks_rolled_back": 0,
                        "chunked_admissions": 0, "chunk_steps": 0,
                        "chunk_prefill_tokens": 0, "cancelled": 0,
                        # Pallas paged-attention dispatch accounting
                        # (use_kernel=True only): fused multi-token
                        # window launches (verify + chunk) vs the total
                        # real query positions fed through the kernel
                        # (1 per active row on a plain decode tick) —
                        # Prometheus tells fused-window from
                        # single-token launches by these two series
                        "kernel_windows": 0, "kernel_positions": 0}

    # ---------------------------------------------------------- telemetry
    def _trace_admit(self, req: Request, slot: int, *,
                     shared: bool = False, chunked: bool = False) -> None:
        """Stamp the admission (first one only: a preempted request's
        re-admission keeps the original, so its prefill span covers the
        recompute) and mark it on the request's trace track."""
        if req.admitted_s is None:
            req.admitted_s = self.clock()
        if self.tracer.enabled:
            self.tracer.instant(
                "admitted", pid=PID_REQUESTS, tid=req.rid,
                args={"slot": slot, "shared": shared, "chunked": chunked,
                      "readmission": req.preemptions > 0})

    def _note_first_token(self, req: Request) -> None:
        """Stamp the request's first *generated* token the moment it
        commits — TTFT is ``first_token_s - submitted_s``, the value the
        trace's first-token instant must reconstruct exactly."""
        if req.first_token_s is not None:
            return
        req.first_token_s = self.clock()
        if self.tracer.enabled:
            self.tracer.instant("first_token", pid=PID_REQUESTS,
                                tid=req.rid, ts=req.first_token_s)

    def _trace_retire(self, req: Request, status: str) -> None:
        """Render the finished request's lifecycle as spans on its trace
        track: the whole-request span plus prefill (admitted -> first
        token) and decode (first token -> done) phases where they
        happened. Emitted at retire time from the request's own stamps,
        so the spans agree with the engine's reported latencies by
        construction."""
        tr = self.tracer
        tr.complete("request", req.submitted_s,
                    req.done_s - req.submitted_s, pid=PID_REQUESTS,
                    tid=req.rid,
                    args={"status": status, "tokens": len(req.out_tokens),
                          "preemptions": req.preemptions})
        if req.first_token_s is None:
            return
        if req.admitted_s is not None:
            tr.complete("prefill", req.admitted_s,
                        req.first_token_s - req.admitted_s,
                        pid=PID_REQUESTS, tid=req.rid)
        tr.complete("decode", req.first_token_s,
                    req.done_s - req.first_token_s,
                    pid=PID_REQUESTS, tid=req.rid)

    # ------------------------------------------------------------- slots
    def free_slots(self) -> list:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _free_slot(self) -> int | None:
        free = self.free_slots()
        return free[0] if free else None

    @property
    def active(self) -> int:
        return self.B - len(self.free_slots())

    @property
    def waiting(self) -> int:
        """Preempted requests parked off-device, pending re-admission."""
        return len(self._waiting)

    def load(self) -> int:
        """Occupied slots + preempted backlog — least-loaded balancing."""
        return self.active + len(self._waiting)

    # --------------------------------------------------------- pool probes
    @staticmethod
    def _eff_prompt(req: Request) -> list:
        """The tokens a (re-)admission must prefill: the prompt plus any
        tokens already generated before a preemption evicted the slot."""
        return req.prompt + req.out_tokens

    def _match_cost(self, eff: list, chunk: int):
        """Resident-or-cached prefix match for ``eff`` and the admission
        cost with it: ``(blocks, matched, need)``. ``need`` counts the
        un-shared blocks, plus one per **cached** matched block (a freed
        block whose index entry survived — reviving it consumes a free
        block, so memory-wise it costs like an allocation even though
        its prefill compute is free), plus ONE extra when the match ends
        inside a *resident* partial tail block — the first append must
        copy-on-write that block, so the gate has to charge the copy up
        front or a batch of tail-sharing admissions would all park on
        their first decode step. (A cached tail revives sole-owned:
        writable in place, no copy.)

        ``chunk`` is the request's chunk width. With chunked prefill
        (the default) the un-shared suffix drains chunk-at-a-time, so
        ANY match is worth using. Only in legacy monolithic mode
        (``chunk == 0``), where the suffix feeds one token per decode
        step, is a match restricted to bounded suffixes —
        ``P - m <= max(block_size, m)`` — so a 16-token preamble in
        front of a 240-token document doesn't trade one batched prefill
        for 240 serial catch-up steps."""
        P = len(eff)
        full = self.pool.blocks_for(P)
        blocks, m = self.pool.match(eff, P - 1)
        if m < self.block_size or \
                (not chunk and P - m > max(self.block_size, m)):
            return [], 0, full
        need = full - len(blocks)
        need += sum(1 for b in blocks if self.pool.refcount(b) == 0)
        if m % self.block_size and self.pool.refcount(blocks[-1]) >= 1:
            need += 1                    # imminent CoW of the shared tail
        return blocks, m, need

    def _chunk_for(self, req: Request) -> int:
        """Chunk width for ``req`` (0 = monolithic admission + serial
        catch-up): the request's override when set — an explicit 0 opts
        the request out of chunking, matching the engine knob's meaning
        — else the engine default; always 0 for families that cannot
        run multi-token windows (recurrent / MoE). Negative overrides
        are clamped here (add_requests rejects them loudly; this keeps
        pre-admission probes like blocks_needed safe on them too)."""
        if not self._paddable:
            return 0
        if req.prefill_chunk is None:
            return self.prefill_chunk
        return max(int(req.prefill_chunk), 0)

    def pending_chunk_tokens(self) -> int:
        """Pending prompt tokens the active slots will feed through
        chunk windows on the next step — the continuation demand the
        scheduler charges against its per-tick prefill budget before
        admitting new prefills."""
        tot = 0
        for i, r in enumerate(self.slot_req):
            if r is not None and self.slot_pending[i]:
                tot += min(len(self.slot_pending[i]),
                           max(self._chunk_for(r), 1))
        if self.prefill_budget is not None:
            tot = min(tot, self.prefill_budget)
        return tot

    def admission_costs(self, req: Request) -> tuple:
        """``(blocks, prefill_tokens)`` admitting ``req`` right now
        would cost — ONE prefix-match walk answers both (the scheduler
        asks per queued candidate per tick, so the walk must not run
        once per number). ``blocks`` is :meth:`blocks_needed`'s
        post-sharing + speculative-watermark figure; ``prefill_tokens``
        is what the admission call itself prefills — the first chunk
        (or whole prompt when monolithic), and 0 for a shared
        admission, whose un-shared suffix is chunk-step work charged as
        continuation on later ticks."""
        eff = self._eff_prompt(req)
        P = len(eff)
        C = self._chunk_for(req)
        first = min(P, C) if C else P
        if not self.paged:
            return 0, first
        spec = self.pool.blocks_for(min(P + self._spec_window(req),
                                        self.max_seq)) \
            - self.pool.blocks_for(P)
        if self.prefix_sharing:
            _, m, need = self._match_cost(eff, C)
            return need + spec, (0 if m >= self.block_size else first)
        return self.pool.blocks_for(P) + spec, first

    def admit_prefill_tokens(self, req: Request) -> int:
        """Prompt tokens admitting ``req`` right now would run through
        prefill in the admission call itself (see
        :meth:`admission_costs`)."""
        return self.admission_costs(req)[1]

    def _spec_window(self, req: Request) -> int:
        """Write positions one speculative step may need past the
        committed length: k proposals + the bonus token's scatter site.
        0 when the engine or the request opts out."""
        if not self.spec_k:
            return 0
        k = self.spec_k if req.speculation is None \
            else min(req.speculation, self.spec_k)
        return k + 1 if k > 0 else 0

    def blocks_needed(self, req: Request) -> int:
        """Pool blocks this request's admission requires right now — the
        **post-sharing** cost: blocks covered by a resident prefix match
        are already paid for (reusing them is free; revived cached
        blocks and a shared partial tail's imminent copy-on-write are
        charged). A speculating engine additionally charges the
        request's **speculative watermark** — the blocks its first
        draft-and-verify window will grow into — so a batch of
        admissions doesn't pass the gate and then mass-park on its
        first speculative step. A CHUNKED admission still charges its
        whole prompt here even though it only allocates its first
        chunk's blocks up front: gating on the first chunk would admit
        prompts the pool cannot finish and mass-park them mid-prompt.
        (0 when not paged — stripe admission is gated on free slots
        alone.)"""
        return self.admission_costs(req)[0]

    def blocks_worst_case(self, req: Request) -> int:
        """Upper bound on the request's block demand, independent of what
        happens to be resident — the "can this EVER be served" gate (a
        prefix match can vanish before a preempted re-admission)."""
        if not self.paged:
            return 0
        return self.pool.blocks_for(len(self._eff_prompt(req)))

    def blocks_available(self) -> int | None:
        return self.pool.available if self.paged else None

    def _admit_ok(self, need: int, planned: int) -> bool:
        avail = self.pool.available - planned
        if need + self.reserve_blocks <= avail:
            return True
        return self.active == 0 and planned == 0 and need <= avail

    def can_admit(self, req: Request, planned_blocks: int = 0, *,
                  need: int | None = None) -> bool:
        """Would admission succeed right now, with ``planned_blocks``
        already promised to earlier picks? Stripe engines admit whenever
        a slot is free; paged engines additionally demand blocks for the
        prompt (at the post-sharing cost) plus ``reserve_blocks`` of
        decode-growth headroom (waived when the engine is idle — an
        empty pool has nothing to protect). Pass ``need`` when the
        caller already holds :meth:`blocks_needed`'s answer, to skip a
        second prefix-match walk."""
        if not self.paged:
            return True
        if need is None:
            need = self.blocks_needed(req)
        return self._admit_ok(need, planned_blocks)

    def memory_pressure(self) -> float:
        """Fraction of KV memory in use: pool occupancy when paged, slot
        occupancy otherwise. The Scheduler sheds on this."""
        if self.paged:
            return self.pool.occupancy
        return self.active / self.B if self.B else 1.0

    def pool_stats(self) -> dict:
        if not self.paged:
            return {"paged": False, "slots": self.B, "active": self.active,
                    "occupancy": self.memory_pressure()}
        return {"paged": True, "waiting": len(self._waiting),
                # logical view: table entries across slots. With prefix
                # sharing this exceeds ``used`` — the physical count —
                # because a shared block is counted once by the pool
                # however many tables map it.
                "logical_blocks": sum(len(b) for b in self.slot_blocks),
                **self.pool.stats()}

    # --------------------------------------------------------- sampling
    @staticmethod
    def _sampling_rows(reqs: list):
        """Per-row sampling params for a prefill group. The counter is
        the request's emission index (``len(out_tokens)``) — a pure
        function of the request, so a sampled stream reproduces across
        engine configurations and preempted re-admissions."""
        n = len(reqs)
        temps = np.zeros(n, np.float32)
        top_ks = np.zeros(n, np.int32)
        seeds = np.zeros(n, np.int32)
        ctrs = np.zeros(n, np.int32)
        for j, r in enumerate(reqs):
            sp = r.sampling or GREEDY
            temps[j] = sp.temperature
            top_ks[j] = sp.top_k
            seeds[j] = sp.seed
            ctrs[j] = len(r.out_tokens)
        return (jnp.asarray(temps), jnp.asarray(top_ks),
                jnp.asarray(seeds), jnp.asarray(ctrs))

    def _sampling_slots(self):
        """Per-slot sampling params for a decode/verify step (greedy
        defaults for empty slots — their draws are discarded)."""
        B = self.B
        temps = np.zeros(B, np.float32)
        top_ks = np.zeros(B, np.int32)
        seeds = np.zeros(B, np.int32)
        ctrs = np.zeros(B, np.int32)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            sp = r.sampling or GREEDY
            temps[i] = sp.temperature
            top_ks[i] = sp.top_k
            seeds[i] = sp.seed
            ctrs[i] = len(r.out_tokens)
        return (jnp.asarray(temps), jnp.asarray(top_ks),
                jnp.asarray(seeds), jnp.asarray(ctrs))

    # --------------------------------------------------------- admission
    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot; False if engine is full."""
        return self.add_requests([req]) == 1

    def _sim_chains(self, eff: list, sim: set) -> None:
        """Record the prefix chains a plain (prefilled) admission will
        register, for in-batch match simulation."""
        bs = self.block_size
        for i in range(self.pool.blocks_for(len(eff))):
            sim.add(tuple(eff[:min((i + 1) * bs, len(eff))]))

    def _sim_match(self, eff: list, max_len: int, sim: set) -> int:
        """Matched length against the union of the real prefix index and
        the chains earlier same-batch plain admissions will register.
        Once the walk leaves the real chain for a sim-promised chunk it
        stays sim-only (the source's later blocks will chain off the
        same canonical prefix, resolved at insertion time)."""
        bs = self.block_size
        pos = 0
        parent = self.pool.ROOT
        while pos + bs <= max_len:
            if tuple(eff[:pos + bs]) in sim:
                parent = False               # sim-only from here on
            else:
                if parent is False:
                    break
                b = self.pool.lookup(parent, tuple(eff[pos:pos + bs]))
                if b is None:
                    break
                parent = b
            pos += bs
        if pos < max_len:
            # partial tail: a sim chain extending past max_len also covers
            # it (the registered block holds at least these tokens)
            tail = tuple(eff[pos:max_len])
            if (parent is not False
                    and self.pool.lookup(parent, tail, partial=True)
                    is not None) \
                    or any(c[:max_len] == tuple(eff[:max_len])
                           and len(c) >= max_len for c in sim):
                return max_len
        return pos

    def add_requests(self, reqs: list) -> int:
        """Admit as many of ``reqs`` (in order, behind any preempted
        requests awaiting re-admission) as free slots AND pool blocks
        allow. Plain admissions prefill each shape-compatible group as
        ONE batched call whose slot insertion happens on device; with
        prefix sharing, a request whose prompt prefix is resident (or is
        being prefilled by an earlier member of this very batch) skips
        prefill for the shared blocks — it acquires them and owes only
        its un-shared suffix, fed through the normal decode steps.
        Returns how many of the *caller's* requests were admitted (a
        prefix of ``reqs``)."""
        for r in reqs:
            if len(r.prompt) > self.max_seq:
                raise ValueError(f"request {r.rid}: prompt length "
                                 f"{len(r.prompt)} > max_seq {self.max_seq}")
            if r.prefill_chunk is not None and r.prefill_chunk < 0:
                raise ValueError(f"request {r.rid}: prefill_chunk "
                                 f"{r.prefill_chunk} < 0")
            if self.paged and \
                    self.pool.blocks_for(len(r.prompt)) > self.pool.total:
                raise ValueError(f"request {r.rid}: prompt needs "
                                 f"{self.pool.blocks_for(len(r.prompt))} "
                                 f"blocks > pool total {self.pool.total}")
        slots_avail = self.free_slots()
        cand = list(self._waiting) + list(reqs)
        take: list = []          # (req, slot, acquired-blocks | None)
        planned = 0
        sim: set = set()         # chains this batch's plain members add
        for r in cand:
            if len(take) >= len(slots_avail):
                break
            eff = self._eff_prompt(r)
            P = len(eff)
            if P > self.max_seq:
                # a preempted request regrew past capacity: it cannot be
                # re-prefilled — finish it as capacity-truncated
                r.done_s = self.clock()
                self.metrics["completed"] += 1
                if self.tracer.enabled:
                    self._trace_retire(r, "truncated")
                self._finished_at_admit.append(r)
                self._waiting.remove(r)
                continue
            slot = slots_avail[len(take)]
            acquired = None
            matched = 0
            if self.paged:
                need = self.pool.blocks_for(P)
                if self.prefix_sharing:
                    blocks, m, cost = self._match_cost(eff,
                                                       self._chunk_for(r))
                    if m >= self.block_size:
                        acquired, matched, need = list(blocks), m, cost
                    else:
                        m_sim = self._sim_match(eff, P - 1, sim)
                        if m_sim >= self.block_size \
                                and (self._chunk_for(r)
                                     or P - m_sim <= max(self.block_size,
                                                         m_sim)):
                            # an earlier member of this batch prefills the
                            # prefix: plan at the post-sharing cost and
                            # resolve the real blocks at insertion time
                            acquired = []
                            need -= self.pool.blocks_for(m_sim)
                            if m_sim % self.block_size:
                                need += 1          # its CoW, like above
                if not self._admit_ok(need, planned):
                    break            # in-order admission: head waits
                planned += need
                if acquired:
                    for b in acquired:
                        # commit the match now: holding a reference keeps
                        # the blocks resident (and indexed) however the
                        # rest of this batch retires or frees. A revived
                        # cached block leaves ``planned`` the moment it
                        # leaves the free list — ``need`` charged it, and
                        # pool.available now reflects it, so keeping both
                        # would double-count it against later picks.
                        if self.pool.refcount(b) == 0:
                            planned -= 1
                        self.pool.acquire(b, owner=slot)
                if acquired is None and self.prefix_sharing:
                    # promise only what this admission actually REGISTERS
                    # in this call: a chunked admission indexes its first
                    # chunk's full blocks now and the rest over later
                    # chunk steps — promising the whole prompt would let
                    # a same-batch peer plan a cheap shared admission,
                    # find the promise broken at insertion time, and
                    # fall back to a plain prefill the block planner
                    # never budgeted
                    C = self._chunk_for(r)
                    n0 = min(P, C) if C else P
                    reg = eff if n0 >= P \
                        else eff[:n0 - n0 % self.block_size]
                    if reg:
                        self._sim_chains(reg, sim)
            take.append((r, slot, acquired, matched))
        n_from_waiting = 0
        for r, _, _, _ in take:
            if self._waiting and self._waiting[0] is r:
                self._waiting.popleft()
                n_from_waiting += 1
        if not take:
            return 0
        # ---- plain admissions first: batched prefill per shape group.
        # A chunked admission contributes only its FIRST chunk here (n0
        # tokens); the remainder becomes the slot's pending queue, fed
        # through decode-interleaved chunk windows by step().
        plain = [(r, s) for r, s, acq, _ in take if acq is None]
        groups: dict = {}
        for n, (req, slot) in enumerate(plain):
            P = len(self._eff_prompt(req))
            C = self._chunk_for(req)
            n0 = min(P, C) if C else P           # first-chunk token count
            if self._solo_prefill:
                key = (n,)                       # one row per prefill call
            elif self._paddable:
                key = _bucket(n0, self.max_seq)
            else:
                key = n0                         # exact-length co-batching
            groups.setdefault(key, []).append((req, slot, n0))
        for key, members in groups.items():
            width = key if isinstance(key, int) else members[0][2]
            toks = np.zeros((len(members), width), np.int32)
            last = np.zeros(len(members), np.int32)
            slots = np.zeros(len(members), np.int32)
            for j, (req, slot, n0) in enumerate(members):
                toks[j, :n0] = self._eff_prompt(req)[:n0]
                last[j] = n0 - 1
                slots[j] = slot
            samp = self._sampling_rows([req for req, _, _ in members])
            if self.paged:
                nxt, logp, pref = self._prefill_paged(
                    self.params, jnp.asarray(toks), jnp.asarray(last),
                    *samp)
                for j, (req, slot, n0) in enumerate(members):
                    eff = self._eff_prompt(req)
                    self._insert_paged(pref, j, slot, eff[:n0],
                                       more=n0 < len(eff))
            else:
                nxt, logp, self.caches = self._admit(
                    self.params, self.caches, jnp.asarray(toks),
                    jnp.asarray(last), jnp.asarray(slots), *samp)
            nxt, logp = np.asarray(nxt), np.asarray(logp)
            for j, (req, slot, n0) in enumerate(members):
                eff = self._eff_prompt(req)
                P = len(eff)
                if slot in self._used_slots:
                    self.metrics["slot_reuses"] += 1
                self._used_slots.add(slot)
                self.slot_req[slot] = req
                self.slot_len[slot] = n0
                self.slot_pending[slot] = list(eff[n0:])
                self._admit_seq += 1
                self._admit_order[slot] = self._admit_seq
                self._trace_admit(req, slot, chunked=n0 < P)
                self.metrics["prefills"] += 1
                self.metrics["prefill_tokens_computed"] += P
                if n0 < P:
                    # mid-prompt: the sampled draw is mid-prompt logits,
                    # discarded — the first real token comes from the
                    # chunk window that drains the pending queue
                    self.metrics["chunked_admissions"] += 1
                    continue
                req.out_tokens.append(int(nxt[j]))
                req.out_logprobs.append(float(logp[j]))
                self._note_first_token(req)
                if self._is_done(req):
                    self._retire(slot)
                    self._finished_at_admit.append(req)
            self.metrics["prefill_batches"] += 1
        # ---- shared admissions after: the whole batch's registrations
        # are visible, so in-batch prefixes resolve to real blocks
        for req, slot, acquired, matched in take:
            if acquired is None:
                continue
            self._admit_shared(req, slot, acquired, matched)
        if self.draft is not None:
            # the draft model caches every admitted prompt too (shared
            # admissions included: the draft has no shared blocks, its
            # stripes are per-slot) — skipping slots that retired at
            # admission (stop token / max_new in the first token). The
            # draft caches everything but the newest committed token
            # (plain admissions just emitted one), which the proposal
            # loop feeds to draw the first proposal.
            members = []
            for req, slot, _, _ in take:
                if self.slot_req[slot] is not req:
                    continue
                eff = self._eff_prompt(req)
                members.append((slot, eff[:-1] if req.out_tokens else eff))
            if members:
                self.draft.admit(members)
        return len(take) - n_from_waiting

    def _extend_match(self, eff: list, slot: int, blocks: list,
                      m: int) -> int:
        """Extend a committed match chain past ``m`` with whatever this
        batch's prefills registered since planning, acquiring each new
        block for ``slot``. Never re-walks from the root — the committed
        chain stays authoritative (a re-walk could diverge onto blocks
        we hold no references to; see the partial-tail-vs-full-block
        race). Only a boundary-ended chain can extend."""
        bs = self.block_size
        if m % bs or not blocks:
            return m
        cap = len(eff) - 1
        parent = blocks[-1]
        while m + bs <= cap:
            b = self.pool.lookup(parent, tuple(eff[m:m + bs]))
            if b is None or b in blocks:
                break
            self.pool.acquire(b, owner=slot)
            blocks.append(b)
            parent = b
            m += bs
        tail = tuple(eff[m:cap])
        if tail and m % bs == 0:
            b = self.pool.lookup(parent, tail, partial=True)
            if b is not None and b not in blocks:
                self.pool.acquire(b, owner=slot)
                blocks.append(b)
                m += len(tail)
        return m

    def _admit_shared(self, req: Request, slot: int, acquired: list,
                      matched: int) -> None:
        """Admit ``req`` into ``slot`` reusing resident prefix blocks.
        ``acquired``/``matched`` are the chain committed at planning time
        (held since, so still resident and indexed); it is extended —
        never re-walked — with blocks this batch's prefills registered.
        An empty ``acquired`` is an in-batch promise resolved against
        the real index here. The un-shared suffix (always >= 1 token:
        the match is capped at P-1 so the last prompt token's logits are
        still computed) becomes the slot's pending queue, fed through
        the ordinary decode steps."""
        eff = self._eff_prompt(req)
        P = len(eff)
        C = self._chunk_for(req)
        if acquired:
            blocks = list(acquired)
            m = self._extend_match(eff, slot, blocks, matched)
        else:
            blocks, m, _ = self._match_cost(eff, C)  # m = 0 if unusable now
            for b in blocks:
                self.pool.acquire(b, owner=slot)
        if m < self.block_size:
            # in-batch promise broken: the source retired inside this
            # very batch and took its index entries with it (nothing was
            # acquired, and the source's freed blocks more than cover a
            # solo plain prefill) — chunked like any plain admission
            n0 = min(P, C) if C else P
            toks = np.asarray([eff[:n0]], np.int32)
            last = np.asarray([n0 - 1], np.int32)
            nxt, logp, pref = self._prefill_paged(
                self.params, jnp.asarray(toks), jnp.asarray(last),
                *self._sampling_rows([req]))
            self._insert_paged(pref, 0, slot, eff[:n0], more=n0 < P)
            self.slot_req[slot] = req
            self.slot_len[slot] = n0
            self.slot_pending[slot] = list(eff[n0:])
            self.metrics["prefill_batches"] += 1
            self.metrics["prefill_tokens_computed"] += P
            if n0 < P:
                self.metrics["chunked_admissions"] += 1
            else:
                req.out_tokens.append(int(np.asarray(nxt)[0]))
                req.out_logprobs.append(float(np.asarray(logp)[0]))
                self._note_first_token(req)
        else:
            self.slot_blocks[slot] = list(blocks)
            self.block_table[slot, :] = 0
            self.block_table[slot, :len(blocks)] = blocks
            self.slot_req[slot] = req
            self.slot_len[slot] = m
            self.slot_pending[slot] = list(eff[m:])
            # chunk-step registration continues the matched chain only
            # from a block boundary: a partial-tail match ends inside a
            # block another sequence registered, and children of a
            # partial parent are unreachable by the match walk
            if m % self.block_size == 0:
                self.slot_reg[slot] = blocks[-1]
                self.slot_reg_pos[slot] = m
            else:
                self.slot_reg[slot] = False
            self.metrics["shared_admissions"] += 1
            self.metrics["prefill_tokens_shared"] += m
            self.metrics["prefill_tokens_computed"] += P - m
        if slot in self._used_slots:
            self.metrics["slot_reuses"] += 1
        self._used_slots.add(slot)
        self._admit_seq += 1
        self._admit_order[slot] = self._admit_seq
        self._trace_admit(req, slot, shared=m >= self.block_size,
                          chunked=bool(self.slot_pending[slot]))
        self.metrics["prefills"] += 1
        if self._is_done(req):
            self._retire(slot)
            self._finished_at_admit.append(req)

    def _insert_paged(self, pref, row: int, slot: int, eff: list, *,
                      more: bool = False) -> None:
        """Allocate the slot's blocks and scatter its prefill KV into the
        pool block-by-block (jitted dynamic_update_slice, pool donated);
        with sharing on, advertise each block's prompt content in the
        prefix index so later admissions can reuse it. ``more``: the
        prompt continues past ``eff`` (a chunked admission's first
        chunk) — the trailing partial block keeps filling with prompt
        content over the coming chunk steps, so its registration is
        deferred to ``_register_chunk_progress`` (registering a
        half-chunk extent now would freeze the index at it)."""
        n_tokens = len(eff)
        n_blk = self.pool.blocks_for(n_tokens)
        blocks = self.pool.alloc(n_blk, owner=slot)
        assert blocks is not None, "admission accounting let an alloc fail"
        self.slot_blocks[slot] = blocks
        self.block_table[slot, :] = 0
        self.block_table[slot, :n_blk] = blocks
        bs = self.block_size
        parent = self.pool.ROOT if self.prefix_sharing else False
        reg_pos = 0
        for i, phys in enumerate(blocks):
            self.caches = self._write_block(
                self.caches, pref, np.int32(row),
                np.int32(i * bs), np.int32(phys))
            end = min((i + 1) * bs, n_tokens)
            if parent is not False and (end - i * bs == bs or not more):
                # thread the canonical block as the next link's parent so
                # duplicate chains converge on one indexed copy; an
                # unregistrable link ends the chain (False sentinel)
                parent = self.pool.register(phys, parent,
                                            tuple(eff[i * bs:end]))
                if parent is None:
                    parent = False
                else:
                    reg_pos = end
        if parent is not False and n_tokens % bs and not more:
            # the final registration was a partial tail: children of a
            # partial parent are unreachable by the match walk, so the
            # chain ends here. A chunked admission (``more``) instead
            # SKIPPED the partial registration above — its chain stays
            # open at the last full block (or ROOT for a sub-block first
            # chunk) and _register_chunk_progress registers the rest as
            # the chunk steps fill it.
            parent = False
        self.slot_reg[slot] = parent
        self.slot_reg_pos[slot] = reg_pos

    def _register_chunk_progress(self, i: int, final: bool) -> None:
        """Advertise prompt content a chunk / catch-up step just wrote
        into slot ``i``'s blocks: every newly FULL block registers in
        the prefix index chained after the slot's canonical frontier,
        and — once the prompt drains (``final``) — the trailing partial
        block registers at the prompt's true tail. These are exactly the
        entries a monolithic prefill would have left, so half-prefilled
        prompts share forward like whole ones. No-op when the chain is
        broken (partial-tail match, CoW below the frontier, duplicate
        registration) — sharing still covers everything before the
        break."""
        parent = self.slot_reg[i]
        if parent is False or not self.prefix_sharing:
            return
        bs = self.block_size
        end = int(self.slot_len[i])    # prompt content resident through
        pos = int(self.slot_reg_pos[i])
        eff = self._eff_prompt(self.slot_req[i])
        while parent is not False and pos + bs <= end:
            parent = self.pool.register(self.slot_blocks[i][pos // bs],
                                        parent, tuple(eff[pos:pos + bs]))
            if parent is None:
                parent = False
            else:
                pos += bs
        if parent is not False and final and pos < end:
            self.pool.register(self.slot_blocks[i][pos // bs], parent,
                               tuple(eff[pos:end]))
            parent = False     # a partial tail ends the walkable chain
            pos = end
        self.slot_reg[i] = parent
        self.slot_reg_pos[i] = pos

    # ------------------------------------------------------------- decode
    def _is_done(self, req: Request) -> bool:
        return (len(req.out_tokens) >= req.max_new_tokens
                or req.finished_by_stop)

    def _release_blocks(self, slot: int) -> None:
        if self.paged and self.slot_blocks[slot]:
            self.pool.free(self.slot_blocks[slot], owner=slot)
            self.slot_blocks[slot] = []
            self.block_table[slot, :] = 0

    def _retire(self, slot: int, *, cancelled: bool = False) -> None:
        req = self.slot_req[slot]
        req.done_s = self.clock()
        if self.tracer.enabled:
            self._trace_retire(req,
                               "cancelled" if cancelled else "completed")
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        self.slot_pending[slot] = []
        self.slot_reg[slot] = False
        self.slot_reg_pos[slot] = 0
        self._release_blocks(slot)
        if self.draft is not None:
            self.draft.reset(slot)
        if cancelled:
            self.metrics["cancelled"] += 1
            return
        self.metrics["completed"] += 1
        if req.finished_by_stop and len(req.out_tokens) < req.max_new_tokens:
            self.metrics["stop_token_exits"] += 1

    def cancel(self, rid: int) -> bool:
        """Cancel request ``rid`` mid-flight: retire its slot (blocks
        freed, draft state reset, slot recyclable this very tick) or
        drop it from the preempted backlog. Returns False when the
        engine doesn't hold it (already finished, or still queued in
        front of the engine — the scheduler owns that case). Must NOT
        be called between ``dispatch_step()`` and ``commit()``: the
        in-flight tick's bookkeeping indexes the slots it dispatched
        with — the async loop applies cancels at the loop boundary."""
        for i, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                self._retire(i, cancelled=True)
                return True
        for r in list(self._waiting):
            if r.rid == rid:
                self._waiting.remove(r)
                r.done_s = self.clock()
                if self.tracer.enabled:
                    self._trace_retire(r, "cancelled")
                self.metrics["cancelled"] += 1
                return True
        return False

    def _preempt(self, slot: int) -> None:
        """Evict a slot under pool exhaustion: free its blocks and queue
        the request for recompute re-admission (its prompt + generated
        tokens prefill again when memory frees — the standard paged-KV
        preemption, trading recompute for not deadlocking the batch).
        Freeing only drops this slot's references: blocks shared with a
        live slot stay resident for it."""
        req = self.slot_req[slot]
        req.preemptions += 1
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        self.slot_pending[slot] = []
        self.slot_reg[slot] = False
        self.slot_reg_pos[slot] = 0
        self._release_blocks(slot)
        if self.draft is not None:
            self.draft.reset(slot)
        self._waiting.append(req)
        self.metrics["preemptions"] += 1
        if self.tracer.enabled:
            self.tracer.instant("preempt", pid=PID_REQUESTS, tid=req.rid,
                                args={"slot": slot,
                                      "generated": len(req.out_tokens)})

    def _ensure_writable(self, i: int, width: int) -> int:
        """Make positions ``[len, len + width)`` of slot ``i`` safe to
        scatter into: **copy-on-write** a shared tail before any write
        would land in it, drop stale prefix-index entries for in-place
        writes, and allocate blocks through the window's last position
        (the speculative **watermark** — ``width = n_spec + 1`` for a
        speculating slot, 1 otherwise). Returns how many positions were
        actually secured: the full width, a degraded count when the pool
        ran out mid-window (the engine speculates less), or 0 — the slot
        cannot even take its next single token and must park."""
        L = int(self.slot_len[i])
        bs = self.block_size
        first_bi = L // bs
        if first_bi < len(self.slot_blocks[i]):
            b = self.slot_blocks[i][first_bi]
            if not self.pool.writable(b):
                # shared tail: writing in place would corrupt the other
                # holders' KV — duplicate the block on device, swap our
                # table entry to the copy, drop our hold on the original
                got = self.pool.alloc(1, owner=i)
                if got is None:
                    # park — and divert this slot's ride-along scatter to
                    # the scratch block: with the table still naming the
                    # SHARED block, the parked write would land in it and
                    # corrupt the other holders' KV (restored below once
                    # the copy, or sole ownership, arrives)
                    self.block_table[i, first_bi] = 0
                    self.metrics["cow_parks"] += 1
                    if self.tracer.enabled:
                        self.tracer.instant("cow_park", pid=PID_POOL,
                                            args={"slot": i,
                                                  "block": int(b)})
                    return 0
                self.caches = self._copy_block(self.caches, np.int32(b),
                                               np.int32(got[0]))
                self.pool.free([b], owner=i)
                self.slot_blocks[i][first_bi] = got[0]
                self.metrics["cow_copies"] += 1
                if self.tracer.enabled:
                    self.tracer.instant("cow_copy", pid=PID_POOL,
                                        args={"slot": i, "src": int(b),
                                              "dst": int(got[0])})
                b = got[0]
            self.block_table[i, first_bi] = b    # also restores a CoW park
            self.pool.prepare_write(b, L % bs)
        last_bi = (L + width - 1) // bs
        while last_bi >= len(self.slot_blocks[i]):
            bi = len(self.slot_blocks[i])
            got = self.pool.alloc(1, owner=i)
            if got is None:
                # secured everything below the unallocated block: the
                # window shrinks (0 when even position L has no block)
                return max(bi * bs - L, 0)
            self.slot_blocks[i].extend(got)
            self.block_table[i, bi] = got[0]
            self.metrics["blocks_grown"] += 1
        return width

    def _grow_or_park(self, active: list, want: dict | None = None) -> dict:
        """Make every active slot's write site(s) safe — ``want[i]``
        positions for a speculating slot (its watermark), one otherwise.
        Slots the pool cannot serve at all park (skip this step, state
        intact); slots it can only partially serve speculate less. If
        nobody can advance, preempt newest admissions until the oldest
        can. Returns {slot: positions secured} (parked slots are removed
        from ``active`` and absent)."""
        secured: dict = {}
        parked = []
        for i in list(active):
            got = self._ensure_writable(i, (want or {}).get(i, 1))
            if got == 0:
                parked.append(i)
                active.remove(i)
            else:
                secured[i] = got
        if parked and not active:
            # total stall: every active slot needs a block and none is
            # free (all blocks are held by the stalled slots themselves).
            order = sorted(parked, key=lambda i: self._admit_order[i])
            while len(order) > 1:
                victim = order.pop()            # newest admission recomputes
                parked.remove(victim)
                self._preempt(victim)
                got = self._ensure_writable(order[0], 1)
                if got:                         # oldest advances first
                    oldest = order.pop(0)
                    parked.remove(oldest)
                    active.append(oldest)
                    secured[oldest] = got
                    break
            if len(order) == 1 and not active:
                # one slot owns the whole pool and still needs more:
                # nothing left to preempt — finish it capacity-truncated
                i = order[0]
                parked.remove(i)
                self._finished_at_admit.append(self.slot_req[i])
                self._retire(i)
        self.metrics["parked_slot_steps"] += len(parked)
        if parked and self.tracer.enabled:
            for i in parked:
                self.tracer.instant("park", pid=PID_REQUESTS,
                                    tid=self.slot_req[i].rid,
                                    args={"slot": i})
        return secured

    def _rollback(self, i: int) -> None:
        """Speculative rollback: return pool blocks past the committed
        length to the pool. Every freed block was allocated for this
        slot's watermark *this or an earlier speculative step* and is
        sole-owned (the window was made writable — copied-on-write out
        of any sharing — before the verify scatter), so no co-holder's
        chain is ever rolled back."""
        keep = self.pool.blocks_for(max(int(self.slot_len[i]), 1))
        extra = self.slot_blocks[i][keep:]
        if extra:
            self.pool.free(extra, owner=i)
            del self.slot_blocks[i][keep:]
            self.block_table[i, keep:] = 0
            self.metrics["spec_blocks_rolled_back"] += len(extra)

    def _spec_step(self, active: list, n_spec, finished: list) -> _Tick:
        """Dispatch one draft-and-verify step. ``n_spec[i]`` proposals
        for each speculating slot (0 for riders: pending catch-up,
        opted-out, or watermark-degraded slots — they feed one real
        token through the same verify batch and advance by one, exactly
        the plain step). The returned tick's commit synchronizes on the
        verify outputs, commits each row's accepted prefix + bonus
        token, rolls the pool back to the committed watermark, and
        advances the draft."""
        k = self.spec_k
        temps, top_ks, seeds, ctrs = self._sampling_slots()
        rows = [i for i in active if n_spec[i] > 0]
        # the draft only needs each row's UNCACHED committed suffix (at
        # most ~2 tokens between rounds) — not an O(prompt + generated)
        # rebuild of the whole context per step
        tails = [None] * self.B
        totals = np.zeros(self.B, np.int64)
        for i in rows:
            r = self.slot_req[i]
            dl, P = int(self.draft.len[i]), len(r.prompt)
            tails[i] = (r.prompt[dl:] + r.out_tokens) if dl < P \
                else r.out_tokens[dl - P:]
            totals[i] = P + len(r.out_tokens)
        proposed, dprobs = self.draft.propose(tails, rows, k, temps,
                                              top_ks, seeds, ctrs)
        self.metrics["draft_steps"] = self.draft.steps_run
        toks = np.zeros((self.B, k + 1), np.int32)
        n_write = np.zeros(self.B, np.int32)
        for i in active:
            r = self.slot_req[i]
            toks[i, 0] = self.slot_pending[i][0] if self.slot_pending[i] \
                else r.out_tokens[-1]
            toks[i, 1:] = proposed[i]
            n_write[i] = n_spec[i] + 1
        ns = jnp.asarray(np.asarray(n_spec, np.int32))
        if self.paged and self.use_kernel:
            self.metrics["kernel_windows"] += 1
            self.metrics["kernel_positions"] += int(
                sum(n_write[i] for i in active))
        if self.paged:
            a, out_toks, lps, self.caches = self._verify(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(self.slot_len), jnp.asarray(self.block_table),
                jnp.asarray(n_write), dprobs, jnp.asarray(proposed), ns,
                temps, top_ks, seeds, ctrs)
        else:
            a, out_toks, lps, self.caches = self._verify(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(self.slot_len), dprobs, jnp.asarray(proposed),
                ns, temps, top_ks, seeds, ctrs)
        self.metrics["decode_steps"] += 1
        self.metrics["verify_steps"] += 1
        return _Tick(lambda: self._commit_spec(active, n_spec, finished,
                                               totals, a, out_toks, lps))

    def _commit_spec(self, active, n_spec, finished, totals, a, out_toks,
                     lps) -> list:
        a, out_toks, lps = np.asarray(a), np.asarray(out_toks), \
            np.asarray(lps)
        k = self.spec_k
        win_proposed = win_accepted = 0     # this verify window's totals
        for i in active:
            r = self.slot_req[i]
            if self.slot_pending[i]:
                # catch-up rider: the fed token was a *prompt* token —
                # its sampled successor only counts once the un-shared
                # suffix is exhausted
                self.slot_len[i] += 1
                self.slot_pending[i].pop(0)
                self._register_chunk_progress(
                    i, final=not self.slot_pending[i])
                if self.paged:
                    self._rollback(i)
                if self.slot_pending[i]:
                    continue
                commit = [int(out_toks[i, 0])]
                lpc = [float(lps[i, 0])]
            else:
                ai = int(min(a[i], n_spec[i]))
                self.slot_len[i] += ai + 1
                commit = [int(t) for t in out_toks[i, :ai + 1]]
                lpc = [float(x) for x in lps[i, :ai + 1]]
                if n_spec[i] > 0:
                    self.metrics["spec_proposed"] += int(n_spec[i])
                    self.metrics["spec_accepted"] += ai
                    win_proposed += int(n_spec[i])
                    win_accepted += ai
                    # draft cache valid through the accepted prefix; it
                    # only ever cached through proposal k-1
                    self.draft.commit(i, int(totals[i]) + min(ai, k - 1))
                if self.paged:
                    self._rollback(i)
            room = r.max_new_tokens - len(r.out_tokens)
            commit = commit[:room]
            for t_idx, t in enumerate(commit):
                if t in r.stop_tokens:       # stop inside the window
                    commit = commit[:t_idx + 1]
                    break
            r.out_tokens.extend(commit)
            r.out_logprobs.extend(lpc[:len(commit)])
            if commit:
                self._note_first_token(r)
            if self._is_done(r):
                finished.append(r)
                self._retire(i)
        if win_proposed and self.tracer.enabled:
            # per-window acceptance: Perfetto renders these as stacked
            # counter series next to the tick-phase track
            self.tracer.counter("speculation",
                                {"proposed": win_proposed,
                                 "accepted": win_accepted}, pid=PID_LOOP)
        return finished

    def _chunk_step(self, active: list, chunk_want: dict,
                    finished: list) -> _Tick:
        """Dispatch one **chunk window** step: every slot with pending prompt
        tokens feeds up to its chunk of them (K/V written at its own
        positions, attending causally against its resident prefix) while
        decode slots ride the same batch with their single next token —
        prompt ingestion interleaved with decode instead of stalling it.
        A row that exhausts its prompt inside the window samples its
        first output token at its last real position; every other
        window draw is discarded. Parked slots ride with ``n_write`` 0
        (paged: all their writes divert to scratch)."""
        W = _bucket(max(chunk_want.get(i, 1) for i in active),
                    self.max_seq)
        toks = np.zeros((self.B, W), np.int32)
        n_write = np.zeros(self.B, np.int32)
        last = np.zeros(self.B, np.int32)
        n_fed: dict = {}
        for i in active:
            r = self.slot_req[i]
            if self.slot_pending[i]:
                c = chunk_want.get(i, 1)
                toks[i, :c] = self.slot_pending[i][:c]
            else:
                c = 1
                toks[i, 0] = r.out_tokens[-1]
            n_fed[i] = c
            n_write[i] = c
            last[i] = c - 1
        temps, top_ks, seeds, ctrs = self._sampling_slots()
        if self.paged and self.use_kernel:
            self.metrics["kernel_windows"] += 1
            self.metrics["kernel_positions"] += sum(n_fed.values())
        if self.paged:
            nxt, logp, self.caches = self._chunk_fn(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(self.slot_len), jnp.asarray(self.block_table),
                jnp.asarray(n_write), jnp.asarray(last), temps, top_ks,
                seeds, ctrs)
        else:
            nxt, logp, self.caches = self._chunk_fn(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(self.slot_len), jnp.asarray(last), temps,
                top_ks, seeds, ctrs)
        self.metrics["decode_steps"] += 1
        self.metrics["chunk_steps"] += 1
        return _Tick(lambda: self._commit_chunk(active, n_fed, finished,
                                                nxt, logp))

    def _commit_chunk(self, active, n_fed, finished, nxt, logp) -> list:
        nxt, logp = np.asarray(nxt), np.asarray(logp)
        for i in active:
            r = self.slot_req[i]
            c = n_fed[i]
            self.slot_len[i] += c
            if self.slot_pending[i]:
                del self.slot_pending[i][:c]
                self.metrics["chunk_prefill_tokens"] += c
                if self.paged:
                    self._register_chunk_progress(
                        i, final=not self.slot_pending[i])
                if self.slot_pending[i]:
                    continue
            r.out_tokens.append(int(nxt[i]))
            r.out_logprobs.append(float(logp[i]))
            self._note_first_token(r)
            if self._is_done(r):
                finished.append(r)
                self._retire(i)
        return finished

    def step(self) -> list:
        """One decode step over all active slots. Equivalent to
        ``dispatch_step().commit()`` — the synchronous drain every test
        and bench compares the async loop against."""
        return self.dispatch_step().commit()

    def dispatch_step(self) -> _Tick:
        """Dispatch one decode step over all active slots (each at its
        own length) — a draft-and-verify multi-token step when the
        engine speculates and any slot has room to, a chunk-window step
        when any slot owes more than one pending prompt token (prompt
        ingestion interleaved with everyone else's decode). Parked slots
        ride the batch but emit nothing.

        All host-side planning (capacity retires, chunk budgeting,
        speculative windows, block growth) happens here, then the jitted
        device call is *launched* — JAX async dispatch returns before
        the computation finishes. The returned :class:`_Tick`'s
        ``commit()`` blocks on the result and applies per-slot
        bookkeeping, returning finished requests. Between dispatch and
        commit the engine's slot state must not be mutated (no
        ``cancel``/``add_requests``) — that window is for *planning*
        (``admission_costs`` etc.), which only reads."""
        finished, self._finished_at_admit = self._finished_at_admit, []
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return _Tick(lambda: finished)
        # any slot past capacity would write out of bounds — finish it now
        for i in list(active):
            if self.slot_len[i] >= self.max_seq:
                finished.append(self.slot_req[i])
                self._retire(i)
                active.remove(i)
        # chunk plan: pending prompt tokens each slot feeds this step,
        # budgeted per tick across slots in admission order (every slot
        # still makes >= 1 token of progress on a dry budget)
        chunk_want: dict = {}
        budget = self.prefill_budget
        for i in sorted(active, key=lambda j: self._admit_order[j]):
            if not self.slot_pending[i]:
                continue
            c = min(len(self.slot_pending[i]),
                    max(self._chunk_for(self.slot_req[i]), 1))
            if budget is not None:
                c = max(1, min(c, budget))
                budget -= c
            chunk_want[i] = c
        chunking = any(c > 1 for c in chunk_want.values())
        # plan speculative windows before securing write sites, so the
        # watermark (window) blocks are granted in the same pass. A
        # chunk tick skips speculation: the window belongs to the
        # chunks, pending rows ride plain in a verify batch anyway, and
        # speculation resumes the moment the prompts drain.
        n_spec = np.zeros(self.B, np.int32)
        if self.spec_k and not chunking:
            for i in active:
                r = self.slot_req[i]
                if self.slot_pending[i]:
                    continue                  # catch-up rides plain
                k = self._spec_window(r) - 1
                if k <= 0:
                    continue
                n_spec[i] = max(0, min(
                    k, self.max_seq - 1 - int(self.slot_len[i]),
                    r.max_new_tokens - len(r.out_tokens) - 1))
        if self.paged and active:
            if chunking:
                want = {i: chunk_want.get(i, 1) for i in active}
            elif n_spec.any():
                want = {i: int(n_spec[i]) + 1 for i in active}
            else:
                want = None
            secured = self._grow_or_park(active, want)
            for i in active:
                # pool pressure degrades the window (possibly to 0: the
                # slot rides this step non-speculatively); a degraded
                # chunk just feeds fewer tokens this step
                n_spec[i] = min(n_spec[i], secured[i] - 1)
                if i in chunk_want:
                    chunk_want[i] = min(chunk_want[i], secured[i])
            chunking = any(chunk_want.get(i, 0) > 1 for i in active)
            finished.extend(self._finished_at_admit)
            self._finished_at_admit = []
        if not active:
            return _Tick(lambda: finished)
        if self.spec_k and any(n_spec[i] > 0 for i in active):
            return self._spec_step(active, n_spec, finished)
        if chunking:
            return self._chunk_step(active, chunk_want, finished)
        tok = np.zeros((self.B, 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue            # parked rows too: their scatter lands
            if self.slot_pending[i]:            # in the scratch block
                tok[i, 0] = self.slot_pending[i][0]   # catch-up prompt token
            else:
                tok[i, 0] = r.out_tokens[-1]
        samp = self._sampling_slots()
        if self.paged and self.use_kernel:
            self.metrics["kernel_positions"] += len(active)
        if self.paged:
            nxt, logp, self.caches = self._decode(
                self.params, jnp.asarray(tok), self.caches,
                jnp.asarray(self.slot_len), jnp.asarray(self.block_table),
                *samp)
        else:
            nxt, logp, self.caches = self._decode(
                self.params, jnp.asarray(tok), self.caches,
                jnp.asarray(self.slot_len), *samp)
        self.metrics["decode_steps"] += 1
        return _Tick(lambda: self._commit_decode(active, finished, nxt,
                                                 logp))

    def _commit_decode(self, active, finished, nxt, logp) -> list:
        nxt, logp = np.asarray(nxt), np.asarray(logp)
        for i in active:
            r = self.slot_req[i]
            self.slot_len[i] += 1
            if self.slot_pending[i]:
                # a shared admission catching up on its un-shared prompt
                # suffix: the fed token was a *prompt* token, so its
                # logits only matter once the suffix is exhausted — then
                # the sample is the first genuinely generated token
                self.slot_pending[i].pop(0)
                if self.paged:
                    self._register_chunk_progress(
                        i, final=not self.slot_pending[i])
                if self.slot_pending[i]:
                    continue
            r.out_tokens.append(int(nxt[i]))
            r.out_logprobs.append(float(logp[i]))
            self._note_first_token(r)
            if self._is_done(r):
                finished.append(r)
                self._retire(i)
        return finished

    # ------------------------------------------------------------- run
    def run(self, requests: list) -> list:
        """Serve a list of requests to completion (batched, slots recycled
        as soon as they free up, preempted requests re-admitted)."""
        pending = list(requests)
        done: list = []
        while pending or self.active or self._waiting \
                or self._finished_at_admit:
            n = self.add_requests(pending)
            del pending[:n]
            done.extend(self.step())
        return done
