"""Slot-native serving engine: device-resident KV cache, batched
prefill admission, and mixed-length continuous-batching decode for one
model (the substrate under every PaaS replica when the payload is an LM).

The engine slots requests into a fixed-capacity batch (contiguous KV
cache, one slot per sequence). Three properties distinguish it from the
lock-step predecessor:

* **Device-side admission** — prefill writes the new sequence's KV into
  its slot with ``jax.lax.dynamic_update_slice`` inside one jitted
  function (cache buffers donated); the full cache never round-trips
  through host numpy. Several waiting requests prefill as one batch.
* **Mixed-length decode** — every slot keeps its own length; one decode
  step ropes, writes, and masks each row at its own position, so slots
  at different depths decode together bit-exactly for dense/recurrent
  families (no padding to the longest active slot). MoE is the one
  caveat: capacity-bounded expert routing shares its per-expert slot
  budget across the co-batched rows, so under expert overflow an MoE
  decode step can drop a token's expert contribution that solo serving
  would keep — inherent to capacity routing, and the reason MoE
  admission prefills one row at a time (see below).
* **Slot recycling mid-flight** — EOS/stop-token early exit frees a slot
  the moment its request finishes; the next waiting request is admitted
  into it while the other slots keep decoding.

Prompts for pure-attention caches (keys ``{k, v}``) are right-padded to
power-of-two buckets so admission compiles O(B x log max_seq) variants,
not one per prompt length; pad positions are never attended (per-slot
length masks them) and are overwritten as decode advances. Recurrent
caches (rwkv / hybrid SSM state) cannot absorb pad tokens, so those
group by exact prompt length instead.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

_MIN_BUCKET = 8


@dataclass
class Request:
    rid: int
    prompt: list                    # token ids
    max_new_tokens: int = 8
    stop_tokens: tuple = ()         # EOS ids -> early exit
    priority: int = 0               # scheduler tier (higher = more urgent)
    deadline_s: float | None = None  # absolute perf_counter SLO deadline
    out_tokens: list = field(default_factory=list)
    submitted_s: float = field(default_factory=time.perf_counter)
    done_s: float | None = None

    @property
    def latency_s(self) -> float:
        return (self.done_s or time.perf_counter()) - self.submitted_s

    @property
    def finished_by_stop(self) -> bool:
        return bool(self.out_tokens) and self.out_tokens[-1] in self.stop_tokens


def _bucket(n: int, cap: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return min(b, cap)


class ServingEngine:
    def __init__(self, model, params, *, batch_size: int = 4,
                 max_seq: int = 256, plan=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.plan = plan
        self.caches = model.init_cache(batch_size, max_seq)
        # MoE routing flattens the whole (rows x tokens) block into one
        # shared per-expert capacity, so pad tokens / co-batched rows can
        # displace real tokens from dispatch — prefill those one row at a
        # time, exact length, to keep admission bit-exact with solo serving.
        is_moe = bool(getattr(model.cfg, "n_experts", 0))
        # pure-attention caches tolerate right-padded prompts (pad KV is
        # masked, then overwritten); recurrent state does not.
        self._paddable = set(self.caches) <= {"k", "v"} and not is_moe
        self._solo_prefill = is_moe
        self.slot_len = np.zeros(batch_size, np.int32)   # tokens in cache
        self.slot_req: list = [None] * batch_size
        self._finished_at_admit: list = []
        self._used_slots: set = set()

        def admit(p, caches, tokens, last_idx, slots):
            """Batched prefill + device-side slot insertion.

            tokens (k, S) right-padded prompts, last_idx (k,) index of each
            row's final real token, slots (k,) destination slot per row.
            Returns (first generated token per row, updated caches).
            """
            logits, pref = model.prefill(p, {"tokens": tokens}, plan,
                                         last_idx=last_idx)
            for j in range(tokens.shape[0]):
                for key in caches:
                    row = jax.lax.dynamic_slice_in_dim(pref[key], j, 1, axis=1)
                    start = (jnp.int32(0), slots[j]) + \
                        (jnp.int32(0),) * (row.ndim - 2)
                    caches[key] = jax.lax.dynamic_update_slice(
                        caches[key], row.astype(caches[key].dtype), start)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nxt, caches

        def decode(p, tok, caches, lengths):
            logits, caches = model.decode_step(p, tok, caches, lengths, plan)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nxt, caches

        self._admit = jax.jit(admit, donate_argnums=(1,))
        self._decode = jax.jit(decode, donate_argnums=(2,))
        self.metrics = {"prefills": 0, "prefill_batches": 0,
                        "decode_steps": 0, "completed": 0,
                        "stop_token_exits": 0, "slot_reuses": 0}

    # ------------------------------------------------------------- slots
    def free_slots(self) -> list:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _free_slot(self) -> int | None:
        free = self.free_slots()
        return free[0] if free else None

    @property
    def active(self) -> int:
        return self.B - len(self.free_slots())

    def load(self) -> int:
        """Occupied slots — consumed by least-loaded balancing."""
        return self.active

    # --------------------------------------------------------- admission
    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot; False if engine is full."""
        return self.add_requests([req]) == 1

    def add_requests(self, reqs: list) -> int:
        """Admit as many of ``reqs`` (in order) as there are free slots,
        prefilling each shape-compatible group as ONE batched call whose
        slot insertion happens on device. Returns #admitted."""
        for r in reqs:
            if len(r.prompt) > self.max_seq:
                raise ValueError(f"request {r.rid}: prompt length "
                                 f"{len(r.prompt)} > max_seq {self.max_seq}")
        free = self.free_slots()
        take = reqs[:len(free)]
        if not take:
            return 0
        groups: dict = {}
        for n, (req, slot) in enumerate(zip(take, free)):
            P = len(req.prompt)
            if self._solo_prefill:
                key = (n,)                       # one row per prefill call
            elif self._paddable:
                key = _bucket(P, self.max_seq)
            else:
                key = P                          # exact-length co-batching
            groups.setdefault(key, []).append((req, slot))
        for key, members in groups.items():
            width = key if isinstance(key, int) \
                else len(members[0][0].prompt)
            toks = np.zeros((len(members), width), np.int32)
            last = np.zeros(len(members), np.int32)
            slots = np.zeros(len(members), np.int32)
            for j, (req, slot) in enumerate(members):
                P = len(req.prompt)
                toks[j, :P] = req.prompt
                last[j] = P - 1
                slots[j] = slot
            nxt, self.caches = self._admit(
                self.params, self.caches, jnp.asarray(toks),
                jnp.asarray(last), jnp.asarray(slots))
            nxt = np.asarray(nxt)
            for j, (req, slot) in enumerate(members):
                req.out_tokens.append(int(nxt[j]))
                if slot in self._used_slots:
                    self.metrics["slot_reuses"] += 1
                self._used_slots.add(slot)
                self.slot_req[slot] = req
                self.slot_len[slot] = len(req.prompt)
                self.metrics["prefills"] += 1
                if self._is_done(req):
                    self._retire(slot)
                    self._finished_at_admit.append(req)
            self.metrics["prefill_batches"] += 1
        return len(take)

    # ------------------------------------------------------------- decode
    def _is_done(self, req: Request) -> bool:
        return (len(req.out_tokens) >= req.max_new_tokens
                or req.finished_by_stop)

    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.done_s = time.perf_counter()
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        self.metrics["completed"] += 1
        if req.finished_by_stop and len(req.out_tokens) < req.max_new_tokens:
            self.metrics["stop_token_exits"] += 1

    def step(self) -> list:
        """One decode step over all active slots (each at its own length).
        Returns finished requests."""
        finished, self._finished_at_admit = self._finished_at_admit, []
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return finished
        # any slot past capacity would write out of bounds — finish it now
        for i in list(active):
            if self.slot_len[i] >= self.max_seq:
                finished.append(self.slot_req[i])
                self._retire(i)
                active.remove(i)
        if not active:
            return finished
        tok = np.zeros((self.B, 1), np.int32)
        for i in active:
            tok[i, 0] = self.slot_req[i].out_tokens[-1]
        nxt, self.caches = self._decode(self.params, jnp.asarray(tok),
                                        self.caches,
                                        jnp.asarray(self.slot_len))
        self.metrics["decode_steps"] += 1
        nxt = np.asarray(nxt)
        for i in active:
            r = self.slot_req[i]
            r.out_tokens.append(int(nxt[i]))
            self.slot_len[i] += 1
            if self._is_done(r):
                finished.append(r)
                self._retire(i)
        return finished

    # ------------------------------------------------------------- run
    def run(self, requests: list) -> list:
        """Serve a list of requests to completion (batched, slots recycled
        as soon as they free up)."""
        pending = list(requests)
        done: list = []
        while pending or self.active or self._finished_at_admit:
            n = self.add_requests(pending)
            del pending[:n]
            done.extend(self.step())
        return done
