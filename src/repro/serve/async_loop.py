"""Async continuous-batching serve loop: dispatch → plan-ahead → commit.

The synchronous ``Scheduler.tick`` serializes host work with the device:
fill slots, block on the decode step, account, repeat — and clients see
tokens only when their request completes. This module restructures the
replica loop around JAX async dispatch so both costs disappear:

- **Pipeline overlap.** ``engine.dispatch_step()`` does all host-side
  planning and *launches* the jitted step; the call returns while the
  device still computes. The loop uses that window for tick N+1's host
  work — admitting late arrivals to the queue and precomputing
  admission costs via ``scheduler.plan_ahead()`` (one prefix-match walk
  per candidate, cached against ``BlockPool.version``) — then blocks in
  ``tick.commit()`` only when the result is actually needed. Host
  planning time hides behind the device step instead of adding to it.

- **Per-token streaming.** Every request may carry an ``on_token``
  callback; after each commit the loop emits the tokens that appeared
  since the last tick, in order. Token values are **bit-identical** to
  the synchronous drain: the engine's streams are deterministic per
  request regardless of batch composition (mixed-length bit-exact
  decode + counter-based sampling), so overlap changes *when* tokens
  arrive, never *what* they are — ``tests/test_streaming.py`` enforces
  this across the full engine grid.

- **Cancellation.** ``StreamHandle.cancel()`` (or a callback raising —
  treated as a client disconnect) retires the slot and frees its
  refcounted KV blocks at the next loop boundary; cancels are never
  applied between dispatch and commit, when slot state must not move.

The loop is *driven*, not threaded, by default: ``run_once()`` pumps one
tick, ``wait(handle)`` pumps until a reply is ready — so tests drive it
under a :class:`~repro.serve.clock.VirtualClock` with scripted arrival
traces and zero wall-clock sleeps. ``start()`` runs the same pump on a
daemon thread (event-woken, no polling sleeps) for live replicas, and
``stream()`` is an ``async`` front-end yielding ``(token, logprob)``
pairs for asyncio servers.

Error taxonomy matches the service layer: queue-full and replica aborts
are retryable ``ServiceError``; sheds and client disconnects are the
client's fault (``RequestError``) and must not poison balancer health.
The balancer additionally refuses to retry a request once its first
token has streamed (the client already observed output).
"""
from __future__ import annotations

import asyncio
import threading
from collections import deque

from repro.core.services import RequestError, ServiceError
from repro.serve.engine import Request
from repro.serve.scheduler import Scheduler
from repro.serve.telemetry import PID_LOOP


class StreamHandle:
    """A submitted request's streaming future.

    ``on_token(token, logprob)`` fires per generated token, in order;
    ``cancel()`` abandons the request at the next loop boundary (the
    reply then carries the tokens generated so far); ``result()`` blocks
    (pumping the loop when it isn't threaded) until the reply dict is
    ready, raising the request's error if it failed.
    """

    def __init__(self, loop: "AsyncServeLoop", req: Request,
                 on_token=None):
        self._loop = loop
        self.request = req
        self.rid = req.rid
        self.on_token = on_token
        self.streamed = 0               # tokens already emitted
        self.cancelled = False
        self.error: Exception | None = None
        self.reply: dict | None = None
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def _finish(self) -> None:
        self._done.set()

    def cancel(self) -> None:
        self._loop.cancel(self)

    def result(self) -> dict:
        return self._loop.wait(self)


class AsyncServeLoop:
    """Continuous-batching pump over one Scheduler/ServingEngine pair."""

    def __init__(self, scheduler: Scheduler, *, name: str = "replica",
                 plan_limit: int = 32):
        self.scheduler = scheduler
        self.engine = scheduler.engine
        self.name = name
        self.plan_limit = plan_limit
        self.clock = scheduler.clock
        self._intake: deque[StreamHandle] = deque()
        self._cancels: deque[StreamHandle] = deque()
        self._live: dict[int, StreamHandle] = {}
        # one lock serializes pumping and intake: the engine is not
        # thread-safe, and callbacks fire with the lock held (reentrant
        # so a callback may cancel its own handle)
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._stopping = threading.Event()
        self._thread: threading.Thread | None = None
        self.metrics = {
            "ticks": 0,                 # committed device steps
            "planned_ahead_ticks": 0,   # ticks that planned >=1 candidate
            "planned": 0,               # total candidates planned in-flight
            "plan_time_s": 0.0,         # host time inside the overlap window
            "commit_wait_s": 0.0,       # host time blocked on the device
        }

    # ------------------------------------------------------------ intake
    def submit(self, req: Request, on_token=None) -> StreamHandle:
        """Hand a request to the loop; returns its stream handle."""
        handle = StreamHandle(self, req, on_token)
        with self._lock:
            self._intake.append(handle)
        self._wake.set()
        return handle

    def cancel(self, handle: StreamHandle) -> None:
        with self._lock:
            if not handle.done:
                self._cancels.append(handle)
        self._wake.set()

    def load(self) -> int:
        """Queued + active + not-yet-admitted work, for least-loaded
        balancing."""
        with self._lock:
            return (len(self._intake) + len(self.scheduler.queue)
                    + self.engine.active)

    # ----------------------------------------------------------- pumping
    def _admit(self) -> None:
        """Move intake handles into the scheduler queue. Queue-only (no
        engine-slot mutation), so this is safe inside the plan-ahead
        window too — late arrivals join tick N+1's plan."""
        while self._intake:
            handle = self._intake.popleft()
            if handle.done:             # cancelled before admission
                continue
            if handle.rid in self._live:
                handle.error = ServiceError(
                    f"{self.name}: duplicate rid {handle.rid}")
                handle._finish()
                continue
            if not self.scheduler.submit(handle.request):
                handle.error = ServiceError(f"{self.name}: queue full")
                handle._finish()
                continue
            self._live[handle.rid] = handle

    def _apply_cancels(self) -> None:
        """Retire cancelled requests (frees slots + refcounted blocks).
        Only called at loop boundaries — never between dispatch and
        commit."""
        while self._cancels:
            handle = self._cancels.popleft()
            if handle.done:
                continue
            handle.cancelled = True
            self.scheduler.cancel(handle.rid)
            self._live.pop(handle.rid, None)
            handle.reply = self._reply(handle.request)
            handle._finish()

    def _collect_shed(self) -> None:
        """Sheds (expired deadline / memory pressure) surface on their
        handles as RequestError — the client's SLO lapsed; retrying
        elsewhere would waste another replica's slots."""
        if not self.scheduler.shed_requests:
            return
        keep = []
        for r in self.scheduler.shed_requests:
            handle = self._live.pop(r.rid, None)
            if handle is None:
                keep.append(r)          # a direct scheduler user's shed
                continue
            handle.error = RequestError(
                f"{self.name}: request {r.rid} shed past its deadline")
            handle._finish()
        self.scheduler.shed_requests[:] = keep

    def _reply(self, r: Request) -> dict:
        return {"tokens": list(r.out_tokens),
                "logprobs": list(r.out_logprobs),
                "latency_s": r.latency_s,
                "replica": self.name}

    def _emit(self) -> None:
        """Stream the tokens each live request gained since last tick. A
        callback that raises is a disconnected client: the request is
        cancelled (slot + blocks recycled) and surfaces RequestError."""
        dead = []
        for rid, handle in self._live.items():
            r = handle.request
            n = len(r.out_tokens)
            if handle.on_token is None:
                handle.streamed = n
                continue
            while handle.streamed < n:
                i = handle.streamed
                try:
                    handle.on_token(r.out_tokens[i], r.out_logprobs[i])
                except Exception as e:
                    handle.error = RequestError(
                        f"{self.name}: client disconnected mid-stream "
                        f"after {i} tokens: {e!r}")
                    dead.append(rid)
                    break
                handle.streamed += 1
        for rid in dead:
            handle = self._live.pop(rid)
            self.scheduler.cancel(rid)
            handle._finish()

    def run_once(self) -> bool:
        """One pipelined tick: admit/cancel → fill → dispatch →
        (plan-ahead window) → commit → account → emit → resolve.
        Returns False when there was nothing to do.

        With a tracer on the engine, every phase lands on the trace's
        serve-loop track as a span — the plan-window and commit-wait
        spans measure the dispatch/commit overlap directly (host work
        hidden vs. time blocked on the device). Timestamps come from
        the loop's clock, so a VirtualClock-driven pump emits a
        deterministic timeline."""
        tr = self.engine.tracer
        trace = tr.enabled
        with self._lock:
            tp = self.clock() if trace else 0.0
            self._apply_cancels()
            if trace:
                now = self.clock()
                tr.complete("apply-cancels", tp, now - tp, pid=PID_LOOP)
                tp = now
            self._admit()
            self.scheduler.fill()
            self._collect_shed()
            if trace:
                now = self.clock()
                tr.complete("fill", tp, now - tp, pid=PID_LOOP)
                tp = now
            eng = self.engine
            if not (eng.active or eng.waiting or eng._finished_at_admit):
                return False
            tick = eng.dispatch_step()
            # ---- overlap window: the device step is in flight --------
            t0 = self.clock()
            if trace:
                tr.complete("dispatch", tp, t0 - tp, pid=PID_LOOP,
                            args={"active": eng.active})
            self._admit()               # late arrivals reach this plan
            planned = self.scheduler.plan_ahead(self.plan_limit)
            t1 = self.clock()
            # ----------------------------------------------------------
            done = tick.commit()
            t2 = self.clock()
            if trace:
                tr.complete("plan-window", t0, t1 - t0, pid=PID_LOOP,
                            args={"planned": planned})
                tr.complete("commit-wait", t1, t2 - t1, pid=PID_LOOP)
            self.scheduler.account(done)
            self.metrics["ticks"] += 1
            self.metrics["planned"] += planned
            if planned:
                self.metrics["planned_ahead_ticks"] += 1
            self.metrics["plan_time_s"] += t1 - t0
            self.metrics["commit_wait_s"] += t2 - t1
            tp = self.clock() if trace else 0.0
            self._emit()
            if trace:
                tr.complete("emit", tp, self.clock() - tp, pid=PID_LOOP,
                            args={"finished": len(done)})
            for r in done:
                handle = self._live.pop(r.rid, None)
                if handle is not None and not handle.done:
                    handle.reply = self._reply(r)
                    handle._finish()
            return True

    def wait(self, handle: StreamHandle) -> dict:
        """Block until the handle resolves — by pumping the loop inline
        when it isn't threaded — then return the reply or raise the
        request's error."""
        if self._thread is not None:
            handle._done.wait()
        else:
            while not handle.done:
                self.run_once()
        if handle.error is not None:
            raise handle.error
        return handle.reply

    def abort(self, error: Exception | None = None) -> int:
        """Fail every in-flight and queued request (replica died / is
        restarting): handles resolve with a retryable ServiceError, and
        scheduler + engine state is torn down so a restart starts clean.
        Returns the number of handles failed."""
        with self._lock:
            err = error if error is not None else ServiceError(
                f"{self.name}: replica aborted mid-stream")
            handles = list(self._live.values()) + list(self._intake) \
                + list(self._cancels)
            self._live.clear()
            self._intake.clear()
            self._cancels.clear()
            n = 0
            for handle in handles:
                if handle.done:
                    continue
                handle.error = err
                handle._finish()
                n += 1
            for req in list(self.scheduler.queue):
                self.scheduler.cancel(req.rid)
            for r in list(self.engine.slot_req):
                if r is not None:
                    self.engine.cancel(r.rid)
            self.engine._waiting.clear()
            self.engine._finished_at_admit.clear()
            return n

    # ---------------------------------------------------------- threaded
    def start(self) -> None:
        """Run the pump on a daemon thread. Event-woken: the thread
        sleeps only when there is no work and wakes on submit/cancel —
        no polling sleeps."""
        if self._thread is not None:
            return
        self._stopping.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"serve-loop:{self.name}")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stopping.set()
        self._wake.set()
        self._thread.join()
        self._thread = None

    def _run(self) -> None:
        while not self._stopping.is_set():
            self._wake.clear()
            if not self.run_once():
                with self._lock:
                    idle = not (self._intake or self._cancels
                                or self.scheduler.queue
                                or self.engine.active
                                or self.engine.waiting)
                if idle and not self._stopping.is_set():
                    self._wake.wait()

    # ----------------------------------------------------------- asyncio
    async def stream(self, req: Request):
        """Async generator yielding ``(token, logprob)`` pairs as they
        materialize, for asyncio front-ends. Pumps the loop inline when
        it isn't threaded; yields control to the event loop between
        ticks so concurrent streams interleave."""
        buf: deque = deque()
        handle = self.submit(req, lambda t, lp: buf.append((t, lp)))
        try:
            while not handle.done:
                if self._thread is None:
                    self.run_once()
                while buf:
                    yield buf.popleft()
                await asyncio.sleep(0)
            while buf:
                yield buf.popleft()
            if handle.error is not None:
                raise handle.error
        finally:
            if not handle.done:
                handle.cancel()
