"""Block-pool KV memory: the host-side allocator behind the paged cache.

One pool owns ``num_blocks`` interchangeable KV blocks of ``block_size``
tokens each (the device tensors live in the engine as
``model.init_paged_cache(num_blocks, block_size)`` — shape
``(L, num_blocks, block_size, Hkv, hd)`` per leaf). A sequence's KV is
scattered over whichever physical blocks were free at admission/growth
time; logical token ``j`` of a slot lives at
``(table[j // block_size], j % block_size)``. Contiguity is never
required, so there is no external fragmentation: any free block
satisfies any allocation, and the only waste is the tail of a
sequence's last block (< ``block_size`` tokens per sequence).

Physical block 0 is **reserved as scratch** and never handed out:
engine slots that are inactive (or parked on pool exhaustion) still
ride through the batched decode step, and their K/V scatter lands in
block 0 via their zeroed table entries instead of corrupting a block
owned by a live sequence. Scratch contents are garbage by design and
are never read by an owned slot (every owned position maps to an
allocated block).

**Reference counting + prefix index (copy-on-write sharing).** A block
may be held by several owners at once: ``alloc`` mints a block at
refcount 1, ``acquire`` adds a holder, ``free`` drops one — the block
returns to the pool only when its last holder lets go, so a shared
block occupies pool memory (and ``used``/``occupancy`` accounting)
exactly once. A freed block's index entry survives as a **cached**
block until ``alloc`` recycles the memory (unindexed blocks are handed
out first): a later same-prefix admission ``acquire``s it back off the
free list — content untouched — so sequential same-template requests
share, not just overlapping ones. On top of the refcounts sits a **prefix index** keyed by
token content: ``register`` records "this block holds these tokens,
chained after that block", and ``match`` walks a new prompt through
the index block by block so admission can ``acquire`` the resident
copy instead of recomputing and re-storing it. Chain links are
(parent block, token tuple) — the parent's identity pins everything
before it, Python dict hashing of the block-sized tuple *is* the
token-hash, and comparing tuples on collision keeps matches exact
rather than probabilistic; one match walk is O(prompt).

Sharing changes the write contract: a block is **writable only at
refcount 1**. Appending into a shared block must copy-on-write first
(the engine owns the device-side copy; the pool just answers
``writable`` and hands out the fresh block), and any in-place write
below a block's registered extent must ``prepare_write`` so the index
stops advertising content that is about to change.

The allocator tracks holders per block purely to make double-free /
foreign-free / double-hold a hard error (and testable as a property)
rather than a silent cross-sequence KV corruption.
"""
from __future__ import annotations

from repro.serve.telemetry import NOOP, PID_POOL

SCRATCH_BLOCK = 0


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` (ceil division; 0 -> 0)."""
    return -(-n_tokens // block_size)


class BlockPool:
    """All-or-nothing allocator over interchangeable, refcounted KV blocks.

    ``total`` excludes the reserved scratch block; ``alloc`` returns the
    physical block ids or ``None`` when the pool cannot satisfy the
    request (the caller parks / sheds — partial grants would deadlock
    admission). Freed blocks go back LIFO so recently-touched device
    memory is reused first.
    """

    def __init__(self, num_blocks: int, block_size: int, *, tracer=None):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # event recorder (serve/telemetry.py): alloc/free/revive
        # instants + an occupancy counter track, all guarded on
        # .enabled so the untraced allocator stays allocation-free
        self.tracer = NOOP if tracer is None else tracer
        # monotonic mutation stamp: bumped by every state change that
        # could alter a prefix match or an admission cost (alloc, free,
        # acquire, register, deregister). The scheduler's plan-ahead
        # stamps its precomputed admission costs with this and re-walks
        # only when the pool actually moved underneath the plan.
        self.version = 0
        self._free = list(range(num_blocks - 1, 0, -1))   # LIFO, 0 reserved
        self._holders: dict[int, list] = {}               # block -> holders
        # prefix index, chained by PARENT BLOCK rather than keyed by the
        # whole token prefix: a registered block's identity pins its
        # content and (recursively) everything before it, so one match
        # step costs O(block_size) token compares instead of hashing an
        # O(position) prefix tuple — pool.match is O(P), not O(P^2),
        # which matters because the scheduler's fill/shed loops call
        # blocks_needed per queued request per tick.
        self._block_key: dict[int, tuple] = {}    # block -> (parent, tokens)
        self._children: dict[object, list[int]] = {}   # parent -> blocks

    # ------------------------------------------------------------ queries
    @property
    def total(self) -> int:
        """Allocatable blocks (scratch excluded)."""
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        """Physical blocks held by >= 1 owner — a shared block counts
        once, however many sequences read it."""
        return self.total - len(self._free)

    @property
    def shared(self) -> int:
        """Blocks currently held by more than one owner."""
        return sum(1 for h in self._holders.values() if len(h) > 1)

    @property
    def occupancy(self) -> float:
        """Fraction of the pool in use, in [0, 1]."""
        return self.used / self.total if self.total else 1.0

    @property
    def cached(self) -> int:
        """Free blocks whose prefix-index entry is still alive — content
        reusable by a future match until ``alloc`` recycles them."""
        return sum(1 for b in self._block_key if b not in self._holders)

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for_tokens(n_tokens, self.block_size)

    def owner_of(self, block: int):
        """Sole holder of ``block`` (or a tuple of holders when shared)."""
        holders = self._holders.get(block)
        if holders is None:
            return None
        return holders[0] if len(holders) == 1 else tuple(holders)

    def refcount(self, block: int) -> int:
        return len(self._holders.get(block, ()))

    def writable(self, block: int) -> bool:
        """In-place writes are legal only for a sole holder; a shared
        block must be copy-on-written first."""
        return self.refcount(block) == 1

    # --------------------------------------------------------- alloc/free
    def alloc(self, n: int, owner) -> list | None:
        """Take ``n`` fresh blocks (refcount 1) for ``owner``; None if
        fewer are free. Free blocks still carrying a **cached** prefix
        entry (see :meth:`free`) are handed out last — and evicted from
        the index the moment they are, so the index never advertises
        content about to be overwritten."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        got: list = []
        evicted = 0
        # LIFO over unindexed blocks first: recently-touched memory is
        # reused AND resident cached prefixes survive as long as any
        # uncached block can serve the allocation
        for i in range(len(self._free) - 1, -1, -1):
            if len(got) == n:
                break
            if self._free[i] not in self._block_key:
                got.append(self._free.pop(i))
        while len(got) < n:                  # evict coldest cached entries
            b = self._free.pop(0)
            self.deregister(b)
            got.append(b)
            evicted += 1
        for b in got:
            self._holders[b] = [owner]
        self.version += 1
        if n and self.tracer.enabled:
            self._trace("alloc", {"n": n, "owner": str(owner),
                                  "cached_evicted": evicted})
        return got

    def acquire(self, block: int, owner) -> None:
        """Add ``owner`` as a holder of ``block``. The block is either
        resident (prefix sharing between live sequences) or a **cached
        free** block still advertised by the index — the latter is
        *revived*: pulled off the free list with ``owner`` as its sole
        holder, its device content untouched since the last free (only
        ``alloc`` recycles content, and it deregisters first). Double-
        hold is a hard error — no table maps the same physical block
        twice for one sequence."""
        holders = self._holders.get(block)
        if holders is None:
            if block in self._block_key:
                self._free.remove(block)     # revive a cached prefix block
                self._holders[block] = [owner]
                self.version += 1
                if self.tracer.enabled:
                    self._trace("revive", {"block": int(block),
                                           "owner": str(owner)})
                return
            raise ValueError(f"block {block}: acquire of a free block")
        if owner in holders:
            raise ValueError(f"block {block}: {owner!r} already holds it")
        holders.append(owner)
        self.version += 1
        if self.tracer.enabled:
            self._trace("share", {"block": int(block),
                                  "holders": len(holders)})

    def free(self, blocks: list, owner) -> None:
        """Drop ``owner``'s hold on each of ``blocks``; a block returns
        to the pool when its last holder lets go — but its prefix-index
        entry **stays alive** (a *cached* block) until ``alloc`` hands
        the memory back out, so a later same-template request can still
        match and revive it (sequential sharing, not just overlapping
        arrivals). Double-free or a free of someone else's block fails
        loudly."""
        released = 0
        for b in blocks:
            holders = self._holders.get(b)
            if holders is None:
                raise ValueError(f"block {b}: freed but not allocated")
            if owner not in holders:
                raise ValueError(f"block {b}: owned by {holders!r}, "
                                 f"freed by {owner!r}")
            holders.remove(owner)
            if not holders:
                del self._holders[b]
                self._free.append(b)
                released += 1
        self.version += 1
        if blocks and self.tracer.enabled:
            self._trace("free", {"n": len(blocks), "released": released,
                                 "owner": str(owner)})

    # ------------------------------------------------------- prefix index
    ROOT = None        # parent of a sequence's first block

    def register(self, block: int, parent, tokens: tuple):
        """Advertise that resident ``block`` holds ``tokens`` (its first
        ``len(tokens)`` positions), chained after registered block
        ``parent`` (``ROOT`` for the first block of a prompt). Returns
        the **canonical** block for this chain position — ``block``
        itself, or the already-registered equivalent when this content
        is a duplicate (callers thread the return value as the next
        block's parent so chains converge on one copy) — or None when
        the block cannot be indexed."""
        tokens = tuple(tokens)
        if not tokens or block not in self._holders:
            return None
        for other in self._children.get(parent, ()):
            if self._block_key[other][1] == tokens:
                return other                   # identical entry: keep first
        if block in self._block_key:
            return None                        # already indexed elsewhere
        self._block_key[block] = (parent, tokens)
        self._children.setdefault(parent, []).append(block)
        self.version += 1
        return block

    def deregister(self, block: int) -> None:
        """Drop ``block``'s index entry — and, recursively, any entries
        chained *after* it: a child's key names this block as parent, and
        once the parent id is recycled with new content a same-id
        re-registration would make those stale chains reachable again
        with the wrong tokens behind them."""
        key = self._block_key.pop(block, None)
        if key is None:
            return
        self.version += 1
        for child in list(self._children.get(block, ())):
            self.deregister(child)
        bucket = self._children[key[0]]
        bucket.remove(block)
        if not bucket:
            del self._children[key[0]]

    def registered_extent(self, block: int) -> int:
        """Tokens the index advertises for ``block`` (0 if unregistered)."""
        key = self._block_key.get(block)
        return len(key[1]) if key else 0

    def prepare_write(self, block: int, offset: int) -> None:
        """Must be called before an in-place write at token ``offset`` of
        ``block``: a write below the registered extent invalidates what
        the index advertises, so the entry is dropped. Writes at or past
        the extent (appends into the unregistered tail) keep it."""
        if not self.writable(block):
            raise ValueError(f"block {block}: write while shared "
                             f"(refcount {self.refcount(block)})")
        if offset < self.registered_extent(block):
            self.deregister(block)

    def lookup(self, parent, chunk: tuple, *,
               partial: bool = False) -> int | None:
        """A resident block chained after ``parent`` whose content is
        ``chunk`` (or, with ``partial``, *starts with* ``chunk``)."""
        if not chunk:
            return None
        chunk = tuple(chunk)
        for b in self._children.get(parent, ()):
            tokens = self._block_key[b][1]
            if tokens == chunk or \
                    (partial and len(tokens) >= len(chunk)
                     and tokens[:len(chunk)] == chunk):
                return b
        return None

    def match(self, tokens, max_len: int | None = None):
        """Longest indexed prefix of ``tokens`` (capped at ``max_len``):
        returns ``(blocks, matched)`` where ``blocks`` are the resident
        blocks covering tokens ``[0, matched)`` in logical order. Walks
        full ``block_size`` chunks down the parent chain, then tries one
        partial tail chunk (shared-tail reuse — the caller copy-on-writes
        before it ever appends there). Pure query: acquires nothing."""
        if not self._block_key:
            return [], 0                       # empty index: free fast path
        tokens = list(tokens)
        if max_len is None:
            max_len = len(tokens)
        max_len = min(max_len, len(tokens))
        bs = self.block_size
        blocks: list = []
        parent = self.ROOT
        pos = 0
        while pos + bs <= max_len:
            b = self.lookup(parent, tuple(tokens[pos:pos + bs]))
            if b is None:
                break
            blocks.append(b)
            parent = b
            pos += bs
        tail = tuple(tokens[pos:max_len])
        if tail:
            b = self.lookup(parent, tail, partial=True)
            if b is not None:
                blocks.append(b)
                pos += len(tail)
        return blocks, pos

    # ---------------------------------------------------------- telemetry
    def _trace(self, name: str, args: dict) -> None:
        """One pool mutation on the trace: the event itself plus an
        occupancy counter sample, so Perfetto draws used/shared/cached
        as a filled track alongside the request and tick spans."""
        self.tracer.instant(name, pid=PID_POOL, args=args)
        self.tracer.counter("pool", {"used": self.used,
                                     "shared": self.shared,
                                     "cached": self.cached}, pid=PID_POOL)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {"total": self.total, "used": self.used,
                "available": self.available, "occupancy": self.occupancy,
                "shared": self.shared, "indexed": len(self._block_key),
                "cached": self.cached, "block_size": self.block_size}

    def check(self) -> None:
        """Assert the allocator invariants (used by the property suite):
        accounting sums to the pool, holders are unique per block, the
        scratch block is never owned or free-listed, and the index only
        advertises resident or cached-free blocks, chained off parents
        that are themselves indexed (no dangling chains a recycled block
        id could resurrect)."""
        assert self.used + self.available == self.total, \
            (self.used, self.available, self.total)
        assert SCRATCH_BLOCK not in self._holders
        assert SCRATCH_BLOCK not in self._free
        assert len(set(self._free)) == len(self._free)
        for b, holders in self._holders.items():
            assert holders, b                        # refcount >= 1
            assert len(set(holders)) == len(holders), (b, holders)
            assert b not in self._free, b
        for b, (parent, tokens) in self._block_key.items():
            assert b in self._holders or b in self._free, \
                f"index advertises unknown block {b}"
            assert tokens, b
            assert parent is self.ROOT or parent in self._block_key, \
                f"block {b} chains off unindexed parent {parent}"
        for parent, bucket in self._children.items():
            for b in bucket:
                assert self._block_key[b][0] == parent
