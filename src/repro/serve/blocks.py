"""Block-pool KV memory: the host-side allocator behind the paged cache.

One pool owns ``num_blocks`` interchangeable KV blocks of ``block_size``
tokens each (the device tensors live in the engine as
``model.init_paged_cache(num_blocks, block_size)`` — shape
``(L, num_blocks, block_size, Hkv, hd)`` per leaf). A sequence's KV is
scattered over whichever physical blocks were free at admission/growth
time; logical token ``j`` of a slot lives at
``(table[j // block_size], j % block_size)``. Contiguity is never
required, so there is no external fragmentation: any free block
satisfies any allocation, and the only waste is the tail of a
sequence's last block (< ``block_size`` tokens per sequence).

Physical block 0 is **reserved as scratch** and never handed out:
engine slots that are inactive (or parked on pool exhaustion) still
ride through the batched decode step, and their K/V scatter lands in
block 0 via their zeroed table entries instead of corrupting a block
owned by a live sequence. Scratch contents are garbage by design and
are never read by an owned slot (every owned position maps to an
allocated block).

The allocator tracks an owner tag per block purely to make
double-ownership a hard error (and testable as a property) rather than
a silent cross-sequence KV corruption.
"""
from __future__ import annotations

SCRATCH_BLOCK = 0


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` (ceil division; 0 -> 0)."""
    return -(-n_tokens // block_size)


class BlockPool:
    """All-or-nothing allocator over interchangeable KV blocks.

    ``total`` excludes the reserved scratch block; ``alloc`` returns the
    physical block ids or ``None`` when the pool cannot satisfy the
    request (the caller parks / sheds — partial grants would deadlock
    admission). Freed blocks go back LIFO so recently-touched device
    memory is reused first.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free = list(range(num_blocks - 1, 0, -1))   # LIFO, 0 reserved
        self._owner: dict[int, object] = {}

    # ------------------------------------------------------------ queries
    @property
    def total(self) -> int:
        """Allocatable blocks (scratch excluded)."""
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.total - len(self._free)

    @property
    def occupancy(self) -> float:
        """Fraction of the pool in use, in [0, 1]."""
        return self.used / self.total if self.total else 1.0

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for_tokens(n_tokens, self.block_size)

    def owner_of(self, block: int):
        return self._owner.get(block)

    # --------------------------------------------------------- alloc/free
    def alloc(self, n: int, owner) -> list | None:
        """Take ``n`` blocks for ``owner``; None if fewer are free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._owner[b] = owner
        return got

    def free(self, blocks: list, owner) -> None:
        """Return ``blocks`` to the pool; ownership is verified so a
        double-free or a free of someone else's block fails loudly."""
        for b in blocks:
            if b not in self._owner:
                raise ValueError(f"block {b}: freed but not allocated")
            if self._owner[b] != owner:
                raise ValueError(f"block {b}: owned by {self._owner[b]!r}, "
                                 f"freed by {owner!r}")
            del self._owner[b]
            self._free.append(b)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {"total": self.total, "used": self.used,
                "available": self.available, "occupancy": self.occupancy,
                "block_size": self.block_size}
