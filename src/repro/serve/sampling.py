"""On-device token sampling for the serving engine.

Greedy argmax was baked into the engine's jitted closures; this module
replaces it with a per-slot parameterized sampler that stays inside the
jit. Three knobs per request (:class:`SamplingParams`):

* ``temperature`` — 0 selects deterministic argmax (the default and the
  tier-1-testable mode); > 0 scales logits before the categorical draw.
* ``top_k`` — 0 keeps the full vocabulary; k restricts the draw to the
  k highest-scoring tokens (ties at the k-th value are all kept).
* ``seed`` — the request's private randomness stream.

**Counter-based keys.** The key for a request's n-th emitted token is
``fold_in(fold_in(key(seed), tag), n)`` — a pure function of the
request's seed and the emission index, never of engine state. That is
what makes sampled streams *reproducible across engine configurations*:
a request emits the same tokens whether it decodes solo or co-batched,
paged or striped, shared-prefix or not — the batching properties the
engine already proves for greedy extend to sampled mode for free. The
engine threads ``(temps, top_ks, seeds, ctrs)`` vectors into its jitted
closures; no key ever lives in engine state. (One carve-out: a row that
actually *speculates* consumes the separate accept stream for its
accept/residual draws — its sampled stream is reproducible per
(seed, speculation) pair, not across speculation settings. Rows riding
a verify batch non-speculatively stay on the token stream, so opting
out of speculation — or never being granted a window — changes
nothing.)

Per-token **logprobs** fall out of the same softmax: every sample
returns ``log_softmax(logits)[token]`` — the *raw* model logprob
(before temperature/top-k shaping), the conventional serving-API
number — and the engine streams it next to the token.

**Speculative acceptance** (:func:`speculative_accept`). The verify
step hands this function target logits for ``k+1`` positions, the draft
model's proposal distributions, and the proposed tokens; it returns how
many leading proposals each row commits plus the bonus/correction
token:

* greedy rows (temperature 0): accept while the proposal equals the
  target argmax — deterministic, and the committed stream is exactly
  the non-speculative greedy stream;
* sampled rows: classic acceptance sampling — accept ``d_j`` with
  probability ``min(1, p(d_j) / q(d_j))`` (``p`` target, ``q`` draft,
  both *after* temperature/top-k shaping), and on first rejection draw
  the correction from the residual ``normalize(max(p - q, 0))``, so the
  committed tokens are distributed exactly as non-speculative sampling
  from the target (Leviathan et al. 2023) even though the draft
  proposed them.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# fold_in tags separating the independent randomness streams a request
# consumes (token draws vs draft proposals vs accept/residual draws)
TOKEN_STREAM = 0
ACCEPT_STREAM = 1
DRAFT_STREAM = 2


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs. The default is greedy argmax."""
    temperature: float = 0.0
    top_k: int = 0                 # 0 = full vocabulary
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def _key(seed, stream, ctr):
    return jax.random.fold_in(jax.random.fold_in(
        jax.random.key(seed), stream), ctr)


def _shaped_logits(logits, temp, top_k):
    """Temperature + top-k shaping of one row's logits (V,) in f32.
    temp <= 0 (greedy) is the caller's branch; here temp is clamped so
    the division stays finite under vmap either way."""
    x = logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
    V = x.shape[-1]
    # top_k = 0 (off) keeps everything: threshold at the global min.
    sorted_desc = jnp.sort(x)[::-1]
    kth = sorted_desc[jnp.clip(top_k - 1, 0, V - 1)]
    thresh = jnp.where(top_k > 0, kth, sorted_desc[-1])
    return jnp.where(x >= thresh, x, jnp.finfo(jnp.float32).min)


def _sample_row(logits, temp, top_k, seed, ctr):
    """One row: (V,) logits -> (token, raw logprob of that token)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits)
    greedy_tok = jnp.argmax(logits).astype(jnp.int32)
    drawn = jax.random.categorical(
        _key(seed, TOKEN_STREAM, ctr),
        _shaped_logits(logits, temp, top_k)).astype(jnp.int32)
    tok = jnp.where(temp <= 0.0, greedy_tok, drawn)
    return tok, logp[tok]


def sample(logits, temps, top_ks, seeds, ctrs):
    """Batched sampling: logits (B, V); temps/top_ks/seeds/ctrs (B,).
    Returns (tokens (B,) int32, logprobs (B,) f32 — raw log-softmax of
    the chosen token). Pure function of its inputs: jit/vmap-safe, and
    deterministic per (seed, ctr) pair."""
    return jax.vmap(_sample_row)(logits, temps, top_ks, seeds, ctrs)


def _draft_row(logits, temp, top_k, seed, ctr, pos):
    """One draft proposal: (token, shaped proposal distribution)."""
    logits = logits.astype(jnp.float32)
    shaped = _shaped_logits(logits, temp, top_k)
    key = jax.random.fold_in(_key(seed, DRAFT_STREAM, ctr), pos)
    drawn = jax.random.categorical(key, shaped).astype(jnp.int32)
    tok = jnp.where(temp <= 0.0, jnp.argmax(logits).astype(jnp.int32),
                    drawn)
    return tok, jax.nn.softmax(shaped)


def draft_propose(logits, temps, top_ks, seeds, ctrs, pos):
    """Draw the draft model's proposal ``pos`` (0..k-1) of the round at
    emission counter ``ctrs``: logits (B, V) -> (tokens (B,), probs
    (B, V) f32 — the shaped distribution each token was drawn from,
    which acceptance sampling needs as ``q``). The key stream is
    disjoint from both the token draws and the accept/residual draws,
    and unique per (request, round, position)."""
    return jax.vmap(_draft_row)(logits, temps, top_ks, seeds, ctrs, pos)


# ------------------------------------------------------- speculative accept
def _accept_row(tlogits, dprobs, proposed, n_spec, temp, top_k, seed, ctr):
    """One row of speculative acceptance.

    tlogits (S, V): target logits at positions [L, L+S); position j's
    logits condition on the committed token plus proposals d_1..d_j.
    dprobs (S-1, V): the draft's (shaped) proposal distributions;
    proposed (S-1,): the draft's proposals d_1..d_{k}. n_spec: how many
    proposals this row actually speculated (0..S-1).

    Returns (a, tokens (S,), logprobs (S,)): commit ``tokens[:a + 1]``
    — ``a`` accepted proposals then the bonus/correction token.
    """
    S = tlogits.shape[0]
    k = S - 1
    tlogits = tlogits.astype(jnp.float32)
    greedy = temp <= 0.0
    rider = n_spec == 0
    tgt_argmax = jnp.argmax(tlogits, axis=-1).astype(jnp.int32)   # (S,)
    shaped = jax.vmap(lambda l: _shaped_logits(l, temp, top_k))(tlogits)
    p = jax.nn.softmax(shaped, axis=-1)                           # (S, V)
    j = jnp.arange(k)
    q_at = jnp.take_along_axis(dprobs, proposed[:, None], axis=-1)[:, 0]
    p_at = jnp.take_along_axis(p[:k], proposed[:, None], axis=-1)[:, 0]
    u = jax.random.uniform(_key(seed, ACCEPT_STREAM, ctr), (k,))
    ok_sampled = u * q_at <= p_at            # accept iff u <= p/q
    ok_greedy = proposed == tgt_argmax[:k]
    ok = jnp.where(greedy, ok_greedy, ok_sampled) & (j < n_spec)
    a = jnp.sum(jnp.cumprod(ok.astype(jnp.int32))).astype(jnp.int32)
    # bonus / correction token at position a
    p_a = p[a]
    rejected = a < n_spec                    # stopped on a refusal
    q_a = dprobs[jnp.minimum(a, k - 1)]
    resid = jnp.maximum(p_a - q_a, 0.0)
    norm = jnp.sum(resid)
    resid = jnp.where(rejected & (norm > 0.0), resid / jnp.maximum(norm, 1e-20),
                      p_a)
    bkey = jax.random.fold_in(_key(seed, ACCEPT_STREAM, ctr), k)
    bonus_sampled = jax.random.categorical(
        bkey, jnp.log(jnp.maximum(resid, 1e-30))).astype(jnp.int32)
    # a RIDER row (n_spec == 0: opted out, catch-up, or window-degraded)
    # is a plain decode step riding the verify batch — its draw must
    # come from the TOKEN stream at the same counter a plain step would
    # use, or a request's sampled stream would depend on whether its
    # co-batched neighbors happen to speculate
    rider_draw = jax.random.categorical(
        _key(seed, TOKEN_STREAM, ctr), shaped[0]).astype(jnp.int32)
    bonus = jnp.where(greedy, tgt_argmax[a],
                      jnp.where(rider, rider_draw, bonus_sampled))
    pos = jnp.arange(S)
    tokens = jnp.where(pos < a, jnp.concatenate([proposed, proposed[-1:]]),
                       jnp.where(pos == a, bonus, 0)).astype(jnp.int32)
    logp_all = jax.nn.log_softmax(tlogits, axis=-1)               # (S, V)
    logprobs = jnp.take_along_axis(logp_all, tokens[:, None], axis=-1)[:, 0]
    return a, tokens, logprobs


def speculative_accept(target_logits, draft_probs, proposed, n_spec,
                       temps, top_ks, seeds, ctrs):
    """Batched draft-and-verify acceptance.

    target_logits (B, S, V) from the multi-token verify step;
    draft_probs (B, S-1, V) shaped draft distributions; proposed
    (B, S-1) draft tokens; n_spec (B,) proposals actually speculated per
    row (rows riding the verify batch non-speculatively pass 0 and get
    exactly one sampled token back). Returns (accepted (B,), tokens
    (B, S), logprobs (B, S)): row b commits ``tokens[b, :accepted[b]+1]``.
    Greedy rows are deterministic: accepted proposals are precisely the
    leading target argmaxes, the correction IS the target argmax, so the
    committed stream equals non-speculative greedy decode token-for-token.
    """
    return jax.vmap(_accept_row)(target_logits, draft_probs, proposed,
                                 n_spec, temps, top_ks, seeds, ctrs)
