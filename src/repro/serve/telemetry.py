"""Serve-loop telemetry: span tracing + a unified metrics registry.

The paper's headline claim is a latency budget ("a normal CV in under
700 ms for a sequential flow of requests"); before this module the
reproduction could only *state* latencies, through counters scattered
across ``engine.metrics``, ``pool.stats()``, ``SchedulerStats`` and
``balancer.stats`` — it could not show *where* a request's time went or
whether the async loop's plan window actually overlapped device
compute. This module is the measurement layer under every serving PR:

* :class:`Tracer` — a clock-injectable event recorder. Components emit
  **spans** (named intervals: a request's queued/prefill/decode phases,
  a tick's fill/dispatch/plan/commit/emit phases) and **instants**
  (admit, park, preempt, copy-on-write, shed, cancel) into a bounded
  ring buffer; :meth:`Tracer.chrome_trace` renders the buffer as Chrome
  trace-event JSON that Perfetto (https://ui.perfetto.dev) loads
  directly — requests as one named track each, the serve loop's tick
  phases as another, pool occupancy as a counter track. The clock is
  injectable, so traces recorded under a
  :class:`~repro.serve.clock.VirtualClock` are **deterministic**: the
  same scripted workload emits byte-identical JSON, which is what lets
  tests assert on traces at all.
* :class:`NoopTracer` — the default everywhere. Every emitter is an
  empty method and every call site is also guarded on ``.enabled``, so
  an untraced engine pays a handful of no-op attribute checks per tick
  (< 0.5 % of a step; ``bench_serving`` gates it) and the hot path
  allocates nothing.
* :class:`MetricsRegistry` — one namespace of counters / gauges /
  histograms with Prometheus text exposition
  (:meth:`MetricsRegistry.prometheus_text`). Existing stats dicts
  (``engine.metrics``, ``pool.stats()``, scheduler/loop/balancer
  counters) plug in as **sources** — callables polled at collection
  time — so the registry unifies them without forking their storage;
  :func:`prometheus_text` merges many registries (one per replica,
  labelled) into one exposition, which is how ``service.py`` and
  ``Supervisor.snapshot`` aggregate across replicas.

Overhead contract (docs/observability.md): tracing is **opt-in**, the
ring buffer bounds memory (oldest events drop first, ``dropped``
counts them), span emission is O(1) appends with no I/O, and exporters
only walk the buffer when asked. The enabled tracer must cost < 2 % on
the closed-loop serving benchmark; the no-op default < 0.5 %.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager

# Trace "process" ids: Perfetto groups tracks by pid, so the serve
# loop's tick phases, the per-request lifecycles, and the pool's
# occupancy counters land in three separately-collapsible groups.
PID_LOOP = 0        # serve-loop tick phases (one thread track)
PID_REQUESTS = 1    # one thread track per request (tid = rid)
PID_POOL = 2        # block-pool counters + events


class NoopTracer:
    """Default tracer: every emitter is a no-op, ``enabled`` is False so
    call sites can skip even argument construction. Exporters render an
    empty trace rather than raising, so ``--trace-out`` on an untraced
    run fails loudly at the *flag* level, not deep in a serve loop."""

    enabled = False

    def instant(self, name, *, pid=0, tid=0, args=None, ts=None):
        pass

    def complete(self, name, start, duration, *, pid=0, tid=0,
                 args=None):
        pass

    def counter(self, name, values, *, pid=0, tid=0, ts=None):
        pass

    @contextmanager
    def span(self, name, *, pid=0, tid=0, args=None):
        yield

    def chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path) -> int:
        raise RuntimeError("no-op tracer records nothing; construct a "
                           "Tracer and pass it to the engine")


NOOP = NoopTracer()


class Tracer(NoopTracer):
    """Bounded in-memory trace recorder with Chrome trace-event export.

    ``clock`` is any zero-argument callable returning seconds
    (``time.perf_counter`` by default, a ``VirtualClock`` in tests);
    every event is stamped with it at emission, so trace timelines and
    the serving stack's latency stats live on one time base when both
    share a clock. ``capacity`` bounds the ring buffer — the hot path
    never grows without bound; the oldest events are evicted first and
    counted in ``dropped``.
    """

    enabled = True

    def __init__(self, clock=time.perf_counter, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.clock = clock
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._events)

    # ------------------------------------------------------------- emit
    def _emit(self, ev: dict) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def instant(self, name, *, pid=0, tid=0, args=None, ts=None):
        """A point event (``ph: "i"``): admit / park / preempt / shed /
        first-token markers."""
        self._emit({"name": name, "ph": "i", "s": "t",
                    "ts": self._us(self.clock() if ts is None else ts),
                    "pid": pid, "tid": tid,
                    **({"args": args} if args else {})})

    def complete(self, name, start, duration, *, pid=0, tid=0,
                 args=None):
        """A closed interval (``ph: "X"``) stamped by the caller —
        lifecycle phases reconstructed at retire time, tick phases
        measured around the work they cover."""
        self._emit({"name": name, "ph": "X", "ts": self._us(start),
                    "dur": self._us(max(duration, 0.0)),
                    "pid": pid, "tid": tid,
                    **({"args": args} if args else {})})

    def counter(self, name, values, *, pid=0, tid=0, ts=None):
        """A counter sample (``ph: "C"``): Perfetto renders each key of
        ``values`` as a stacked series (pool occupancy, spec accepts)."""
        self._emit({"name": name, "ph": "C",
                    "ts": self._us(self.clock() if ts is None else ts),
                    "pid": pid, "tid": tid, "args": dict(values)})

    @contextmanager
    def span(self, name, *, pid=0, tid=0, args=None):
        """Context-manager form of :meth:`complete` for host-side work
        measured in place."""
        t0 = self.clock()
        try:
            yield
        finally:
            self.complete(name, t0, self.clock() - t0, pid=pid, tid=tid,
                          args=args)

    @staticmethod
    def _us(t: float) -> float:
        # Chrome trace timestamps are microseconds; rounding to 0.1 us
        # keeps the JSON stable against float-repr noise without losing
        # anything a serve loop can resolve
        return round(t * 1e6, 1)

    # ----------------------------------------------------------- export
    def chrome_trace(self) -> dict:
        """The ring buffer as a Chrome trace-event object (Perfetto /
        chrome://tracing loadable). Process/thread metadata names the
        tracks; request tracks are labelled by rid. Deterministic for a
        deterministic clock: events render in emission order with
        sorted keys, so two identical scripted runs serialize to
        byte-identical JSON."""
        events = [{"name": "process_name", "ph": "M", "pid": pid,
                   "tid": 0, "args": {"name": label}}
                  for pid, label in ((PID_LOOP, "serve-loop"),
                                     (PID_REQUESTS, "requests"),
                                     (PID_POOL, "kv-block-pool"))]
        rids = sorted({e["tid"] for e in self._events
                       if e["pid"] == PID_REQUESTS})
        events.extend({"name": "thread_name", "ph": "M",
                       "pid": PID_REQUESTS, "tid": rid,
                       "args": {"name": f"request {rid}"}}
                      for rid in rids)
        events.extend(self._events)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write_chrome_trace(self, path) -> int:
        """Serialize to ``path``; returns the number of trace events
        written (metadata included)."""
        trace = self.chrome_trace()
        with open(path, "w", encoding="utf-8") as f:
            json.dump(trace, f, sort_keys=True, separators=(",", ":"))
            f.write("\n")
        return len(trace["traceEvents"])


# =========================================================== metrics
def _sanitize(name: str) -> str:
    """Prometheus metric names allow [a-zA-Z0-9_:]; everything else
    (the dots of ``serving.open_loop.ttft``-style row names, slashes of
    replica names) maps to ``_``."""
    return "".join(c if c.isalnum() or c in "_:" else "_" for c in name)


class Counter:
    """Monotonic count (``inc`` only; resets are a new process)."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up ({n})")
        self.value += n

    def samples(self):
        return [("", self.value)]


class Gauge:
    """Point-in-time value (queue depth, pool occupancy)."""

    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def samples(self):
        return [("", self.value)]


# Latency-shaped default buckets (seconds): sub-ms host work through
# multi-second drains, plus the paper's 700 ms budget as an edge.
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   0.7, 1.0, 2.5, 5.0, 10.0)


class Histogram:
    """Cumulative-bucket histogram, Prometheus exposition semantics:
    ``_bucket{le=...}`` counts observations <= bound, plus ``_sum`` and
    ``_count``."""

    __slots__ = ("name", "help", "buckets", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets=DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError(f"{name}: need >= 1 bucket")
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1

    def samples(self):
        out = []
        cum = 0
        for b, c in zip(self.buckets, self.counts):
            cum = c  # counts are already cumulative per observe()
            out.append((f'_bucket{{le="{b}"}}', cum))
        out.append(('_bucket{le="+Inf"}', self.count))
        out.append(("_sum", self.sum))
        out.append(("_count", self.count))
        return out


class MetricsRegistry:
    """One namespace of instruments + polled sources, with Prometheus
    text exposition.

    ``labels`` stamp every sample (e.g. ``{"replica": "lm/0"}``) so
    per-replica registries merge into one exposition without name
    collisions. ``source(prefix, fn)`` registers a zero-arg callable
    returning a flat dict of numbers — the bridge that puts
    ``engine.metrics`` / ``pool.stats()`` / scheduler / loop / balancer
    counters behind this one registry instead of five ad-hoc dicts:
    sources are polled at :meth:`collect` time and rendered as gauges
    (their dict semantics: current value, resettable by the owner).
    Non-numeric source values are skipped."""

    def __init__(self, labels: dict | None = None):
        self.labels = dict(labels or {})
        self._instruments: dict[str, object] = {}
        self._sources: list[tuple[str, object]] = []

    # ------------------------------------------------------ instruments
    def _get(self, cls, name: str, help: str, **kw):
        name = _sanitize(name)
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name, help, **kw)
        elif not isinstance(inst, cls):
            raise ValueError(f"{name}: already registered as "
                             f"{type(inst).__name__}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def source(self, prefix: str, fn) -> None:
        """Poll ``fn()`` (a flat ``{name: number}`` dict) at collect
        time, exposing each key as gauge ``{prefix}_{key}``."""
        self._sources.append((prefix, fn))

    # ------------------------------------------------------- collection
    def collect(self) -> list:
        """``(name, kind, help, labels, samples)`` tuples for every
        instrument plus every source key — ``samples`` is a list of
        ``(suffix, value)``."""
        out = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            out.append((inst.name, inst.kind, inst.help, self.labels,
                        inst.samples()))
        for prefix, fn in self._sources:
            vals = fn()
            for key in sorted(vals):
                v = vals[key]
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                out.append((_sanitize(f"{prefix}_{key}"), "gauge", "",
                            self.labels, [("", float(v))]))
        return out

    def prometheus_text(self) -> str:
        return prometheus_text([self])


def _render_labels(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{_sanitize(k)}="{v}"'
                    for k, v in sorted(labels.items()))
    return "{" + body + "}"


def prometheus_text(registries) -> str:
    """Merge many registries (one per replica, each with distinguishing
    labels) into one Prometheus text exposition: ``# HELP``/``# TYPE``
    emitted once per metric name, samples from every registry under
    it."""
    by_name: dict[str, list] = {}
    meta: dict[str, tuple] = {}
    for reg in registries:
        for name, kind, help, labels, samples in reg.collect():
            by_name.setdefault(name, []).append((labels, samples))
            if name not in meta or (help and not meta[name][1]):
                meta[name] = (kind, help)
    lines = []
    for name in sorted(by_name):
        kind, help = meta[name]
        if help:
            lines.append(f"# HELP {name} {help}")
        lines.append(f"# TYPE {name} {kind}")
        for labels, samples in by_name[name]:
            for suffix, value in samples:
                if "{" in suffix and labels:
                    # fold the registry labels in with the sample's own
                    # (histogram buckets carry le="...")
                    base, inner = suffix.split("{", 1)
                    lab = _render_labels(labels)
                    lines.append(f"{name}{base}{lab[:-1]},{inner}"
                                 f" {_fmt(value)}")
                else:
                    lines.append(f"{name}{suffix}{_render_labels(labels)}"
                                 f" {_fmt(value)}")
    return "\n".join(lines) + "\n"


def _fmt(v) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)
