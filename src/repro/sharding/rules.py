"""Divisibility-aware sharding policy: 2-D (FSDP x TP) weights, batch- or
sequence-sharded activations/caches.

Baseline policy (hillclimbed variants live in ``repro.sharding.variants``):

* weight matrices: input dim over the FSDP axes ``("pod","data")``,
  output dim over ``"model"`` — except output projections (``w_o`` /
  ``w_out``), whose *input* dim takes ``"model"`` (Megatron pairing, so
  column-parallel -> row-parallel needs no resharding).
* MoE experts: expert dim over ``"model"`` when divisible (EP, kimi-k2),
  else expert d_ff over ``"model"`` (TP, grok-1); rows over FSDP.
* activations: batch over ``("pod","data")``.
* decode KV cache: sequence over ``"model"`` (flash-decoding split);
  batch=1 long-context shards the sequence over *all* axes.
* any dim that does not divide its axes falls back to replication
  (e.g. whisper's vocab 51865, hymba's 32001).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axsize(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    return int(np.prod([mesh.shape[n] for n in names])) if names else 1


DECODE_TP_WEIGHT_BUDGET = 6 * 2**30   # bytes/device for gather-free decode


@dataclass
class ParallelPlan:
    mesh: Mesh | None
    batch_axes: tuple = ("data",)          # includes "pod" when present
    model_axis: str = "model"
    moe_mode: str | None = None            # "ep" | "tp" | None
    kind: str = "train"                    # train | prefill | decode
    weight_fsdp: tuple = ("data",)         # axes sharding weight rows
    _cfg: Any = field(default=None, repr=False)

    # ------------------------------------------------------------- factory
    @classmethod
    def make(cls, mesh, cfg, shape_kind: str = "train"):
        if mesh is None:
            return cls(None, (), moe_mode=None, kind=shape_kind,
                       weight_fsdp=(), _cfg=cfg)
        batch_axes = tuple(n for n in ("pod", "data") if n in mesh.shape)
        moe_mode = None
        if cfg is not None and cfg.n_experts:
            nm = mesh.shape["model"]
            moe_mode = "ep" if cfg.n_experts % nm == 0 else "tp"
            if moe_mode == "tp":
                assert cfg.moe_d_ff % nm == 0, "MoE unshardable on this mesh"
        # Decode latency rule (§Perf, deepseek-7b x decode_32k): FSDP row
        # sharding forces a per-layer weight all-gather per TOKEN at
        # decode. When the weights fit the model axis alone, replicate
        # them over the batch axes instead — gather-free decode. Models
        # too large for that (nemotron/grok/kimi) keep 2-D sharding and
        # pay the gather: capacity wins over latency.
        weight_fsdp = batch_axes
        if shape_kind == "decode" and cfg is not None:
            per_dev = 2 * cfg.n_params() / mesh.shape["model"]
            if per_dev <= DECODE_TP_WEIGHT_BUDGET:
                weight_fsdp = ()
        return cls(mesh, batch_axes, moe_mode=moe_mode, kind=shape_kind,
                   weight_fsdp=weight_fsdp, _cfg=cfg)

    # ------------------------------------------------------------- helpers
    def axis_size(self, names) -> int:
        return _axsize(self.mesh, names)

    def _div(self, dim: int, names):
        """Return axes (possibly reduced or None) that evenly divide dim."""
        if self.mesh is None:
            return None
        if isinstance(names, str):
            names = (names,)
        while names:
            if dim % _axsize(self.mesh, names) == 0:
                return names if len(names) > 1 else names[0]
            names = names[1:]   # drop leading (biggest-group) axis
        return None

    def ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain_act(self, x):
        if self.mesh is None:
            return x
        b = self._div(x.shape[0], self.batch_axes)
        return jax.lax.with_sharding_constraint(
            x, self.ns(P(b, *([None] * (x.ndim - 1)))))

    def constrain_residual(self, x):
        """Sequence-parallel residual stream: between blocks, activations
        (B, S, d) are sharded batch x seq over (batch_axes, model) so the
        remat residual stack shrinks by the model-axis size (Megatron-SP).
        The partitioner inserts the SP<->TP transitions around attention."""
        if self.mesh is None:
            return x
        if x.ndim != 3:
            return self.constrain_act(x)
        b = self._div(x.shape[0], self.batch_axes)
        s = self._div(x.shape[1], (self.model_axis,))
        return jax.lax.with_sharding_constraint(x, self.ns(P(b, s, None)))

    def constrain_logits(self, x):
        if self.mesh is None:
            return x
        b = self._div(x.shape[0], self.batch_axes)
        v = self._div(x.shape[-1], self.model_axis)
        return jax.lax.with_sharding_constraint(
            x, self.ns(P(b, *([None] * (x.ndim - 2)), v)))

    # ------------------------------------------------------------- params
    def param_spec(self, path: tuple, shape: tuple) -> P:
        """path: tuple of str keys from the params pytree root."""
        names = [str(getattr(k, "key", k)) for k in path]
        leaf = names[-1]
        fsdp, model = self.weight_fsdp, self.model_axis
        stacked = "blocks" in names  # leading L axis
        dims = list(shape[1:]) if stacked else list(shape)
        nd = len(dims)

        def build(spec_tail):
            full = ([None] + spec_tail) if stacked else spec_tail
            return P(*full)

        if nd <= 1:
            return build([None] * nd)

        is_moe = "moe" in names and leaf in ("w_in", "w_out", "w_gate")
        if is_moe and nd == 3:
            E, a, b = dims
            if self.moe_mode == "ep":
                e_ax = self._div(E, model)
                # rows = input dim of the matmul
                r = 1 if leaf != "w_out" else 2
                tail = [e_ax, None, None]
                tail[r] = self._div(dims[r], fsdp)
                return build(tail)
            # tp mode: d_ff dim over model, other dim over fsdp
            f_dim = 2 if leaf != "w_out" else 1
            o_dim = 1 if leaf != "w_out" else 2
            tail = [None, None, None]
            tail[f_dim] = self._div(dims[f_dim], model)
            tail[o_dim] = self._div(dims[o_dim], fsdp)
            return build(tail)

        if leaf == "embed":
            return P(self._div(dims[0], model), self._div(dims[1], fsdp))
        if leaf in ("lm_head",):
            return P(self._div(dims[0], fsdp), self._div(dims[1], model))
        if leaf in ("pos_embed",):
            return build([None, self._div(dims[-1], fsdp)]) if nd == 2 \
                else P(None, None)
        if leaf == "router":
            return build([None] * nd)

        if nd == 2:
            din, dout = dims
            # Head-boundary-aware attention TP (§Perf, qwen2-vl x
            # prefill_32k): column-sharding q/k/v projections is only
            # legal along whole heads. Slicing through a head's hd makes
            # the score dot PARTIAL over the contracting dim, which the
            # partitioner completes with an all-reduce of the full
            # (B,H,S,T) score tensor per layer per chunk (observed 1.3 TB
            # per prefill step). When heads don't divide the model axis,
            # replicate those columns instead (the projections are small)
            # and let sequence parallelism carry the attention sharding.
            nm = _axsize(self.mesh, model)
            cfg = self._cfg
            if cfg is not None and leaf in ("w_q", "w_kv", "w_o"):
                heads_ok = cfg.n_heads % nm == 0
                kv_ok = cfg.n_kv_heads % nm == 0 or cfg.n_kv_heads == 0
                if leaf == "w_q" and not heads_ok:
                    return build([self._div(din, fsdp), None])
                if leaf == "w_kv" and not kv_ok:
                    return build([self._div(din, fsdp), None])
                if leaf == "w_o" and not heads_ok:
                    return build([None, self._div(dout, fsdp)])
            if leaf in ("w_o", "w_out", "w_v"):   # row-parallel outputs
                return build([self._div(din, model), self._div(dout, fsdp)])
            return build([self._div(din, fsdp), self._div(dout, model)])
        return build([None] * nd)

    def param_shardings(self, params_tree):
        """Map a pytree of arrays/ShapeDtypeStructs -> NamedShardings."""
        if self.mesh is None:
            return None
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: self.ns(self.param_spec(path, leaf.shape)),
            params_tree)

    # ------------------------------------------------------------- batches
    def batch_spec(self, leaf_path: tuple, shape: tuple) -> P:
        name = str(getattr(leaf_path[-1], "key", leaf_path[-1]))
        if not shape:
            return P()
        b = self._div(shape[0], self.batch_axes)
        return P(b, *([None] * (len(shape) - 1)))

    def cache_spec(self, leaf_path: tuple, shape: tuple) -> P:
        """Decode caches: (L, B, T, ...) K/V seq-sharded over model;
        batch=1 shards T over every axis."""
        name = str(getattr(leaf_path[-1], "key", leaf_path[-1]))
        L, B = shape[0], shape[1]
        b = self._div(B, self.batch_axes)
        if name in ("k", "v"):
            T = shape[2]
            if b is None:
                seq = self._div(T, self.batch_axes + (self.model_axis,))
            else:
                seq = self._div(T, self.model_axis)
            return P(None, b, seq, None, None)
        if name in ("xk", "xv"):
            return P(None, b, None, None, None)
        if name == "state":          # rwkv (L,B,H,hd,hd)
            h = self._div(shape[2], self.model_axis)
            return P(None, b, h, None, None)
        if name == "ssm_state":      # (L,B,di,N)
            di = self._div(shape[2], self.model_axis)
            return P(None, b, di, None)
        return P(*([None, b] + [None] * (len(shape) - 2)))

    def input_shardings(self, specs: dict):
        """NamedShardings for the dry-run input tree (train/prefill batch
        or decode (token, cache, cache_len))."""
        if self.mesh is None:
            return None

        def assign(path, leaf):
            names = [str(getattr(k, "key", k)) for k in path]
            if "cache" in names:
                return self.ns(self.cache_spec(path, leaf.shape))
            return self.ns(self.batch_spec(path, leaf.shape))

        return jax.tree_util.tree_map_with_path(assign, specs)
