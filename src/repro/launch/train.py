"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        [--steps 50] [--reduced/--full] [--mesh-shape 2,2] [--seq 128]

On this CPU container ``--reduced`` (default) trains the family-preserving
small variant on however many devices exist; ``--full`` requires a real
pod (it will build the production mesh and the full-size config — on CPU
that only makes sense under the dry-run, which is ``repro.launch.dryrun``).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import build_model
from repro.sharding.rules import ParallelPlan
from repro.train import optimizer as opt_mod
from repro.train.data import DataConfig, PackedLMDataset
from repro.train.train_loop import TrainerConfig, train


def make_mesh(shape_str: str | None):
    if not shape_str:
        n = len(jax.devices())
        if n == 1:
            return None
        return jax.make_mesh((1, n), ("data", "model"))
    dims = tuple(int(x) for x in shape_str.split(","))
    names = ("data", "model")[-len(dims):] if len(dims) <= 2 else \
        ("pod", "data", "model")
    return jax.make_mesh(dims, names)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (pod hardware)")
    ap.add_argument("--mesh-shape", default="",
                    help="e.g. 4,2 -> (data=4, model=2)")
    ap.add_argument("--ckpt-root", default="checkpoints")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(cfg.reduced(), dtype=jax.numpy.float32,
                                  vocab_size=4096)
    mesh = make_mesh(args.mesh_shape)
    plan = ParallelPlan.make(mesh, cfg, "train")
    model = build_model(cfg)

    n_dev = mesh.size if mesh else 1
    print(f"training {args.arch} ({cfg.family}) on {n_dev} device(s); "
          f"mesh={dict(mesh.shape) if mesh else None}")

    data = PackedLMDataset(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      batch_size=args.batch,
                                      n_documents=2048))
    tc = TrainerConfig(
        n_steps=args.steps, log_every=max(args.steps // 10, 1),
        ckpt_root=args.ckpt_root, ckpt_name=args.arch,
        opt=opt_mod.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps))
    res = train(model, data, tc, plan=plan)
    for h in res.history:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f}")
    losses = [h["loss"] for h in res.history]
    print(f"{res.steps_per_s:.2f} steps/s; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}; checkpoint: {args.ckpt_root}/{args.arch}-final")
    if not np.isfinite(losses[-1]):
        raise SystemExit("non-finite loss")


if __name__ == "__main__":
    main()
