"""Serving launcher: slot-native continuous-batching engine for one
architecture, behind an SLO-aware scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        [--requests 6] [--batch 4] [--max-new 8] [--policy spf]

Serves synthetic token requests through the mixed-length engine (reduced
config on CPU). For the multi-model parallel-PaaS serving of the paper,
see examples/serve_parallel_pipeline.py; for pod-scale serving shapes,
see repro.launch.dryrun (decode_32k / long_500k).
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import POLICIES, Scheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--policy", default="fifo", choices=POLICIES)
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-request deadline; 0 = no SLO")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill width (default: engine auto; "
                         "0 = monolithic admission)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="per-tick prefill token budget (chunk "
                         "continuation + new admissions)")
    ap.add_argument("--stream", action="store_true",
                    help="serve through the async dispatch/plan-ahead/"
                         "commit loop with per-token streaming (reports "
                         "TTFT and host/device overlap)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record request lifecycles, tick phases, and "
                         "pool events to a Chrome trace-event JSON "
                         "(open in Perfetto / chrome://tracing)")
    args = ap.parse_args()

    tracer = None
    if args.trace_out:
        from repro.serve.telemetry import Tracer
        tracer = Tracer()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              dtype=jax.numpy.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, batch_size=args.batch,
                        max_seq=args.max_seq,
                        prefill_chunk=args.prefill_chunk,
                        prefill_budget=args.prefill_budget,
                        tracer=tracer)

    sched = Scheduler(eng, policy=args.policy,
                      prefill_budget=args.prefill_budget)

    import time
    rng = jax.random.key(1)
    reqs = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        # mixed prompt lengths exercise per-slot decode
        plen = max(2, args.prompt_len - (i % 4) * 2)
        prompt = jax.random.randint(k, (plen,), 2,
                                    cfg.vocab_size).tolist()
        deadline = (time.perf_counter() + args.slo_ms / 1e3
                    if args.slo_ms else None)
        reqs.append(Request(rid=i, prompt=prompt, deadline_s=deadline,
                            max_new_tokens=args.max_new))

    print(f"serving {args.requests} requests on {args.arch} "
          f"({cfg.family}, reduced) — engine batch {args.batch}, "
          f"policy {args.policy}"
          + (" — async streaming loop" if args.stream else ""))
    if args.stream:
        from repro.serve.async_loop import AsyncServeLoop
        loop = AsyncServeLoop(sched, name=f"{args.arch}/0")
        ttft: dict = {}
        handles = []
        for r in reqs:
            def _first(tok, logp, rid=r.rid, t0=time.perf_counter()):
                ttft.setdefault(rid, time.perf_counter() - t0)
            handles.append(loop.submit(r, _first))
        done = []
        for h in handles:
            try:
                loop.wait(h)
                done.append(h.request)
            except Exception as e:  # shed / queue full
                print(f"  req {h.rid}: {e}")
        if ttft:
            print(f"TTFT p50={statistics.median(ttft.values())*1e3:.0f}ms "
                  f"max={max(ttft.values())*1e3:.0f}ms; "
                  f"loop: {loop.metrics['ticks']} ticks, "
                  f"{loop.metrics['planned']} admissions planned in-flight "
                  f"(plan {loop.metrics['plan_time_s']*1e3:.0f}ms hidden "
                  f"behind {loop.metrics['commit_wait_s']*1e3:.0f}ms of "
                  f"device wait)")
    else:
        for r in reqs:
            sched.submit(r)
        done = sched.drain()
    lats = [r.latency_s for r in done]
    toks = sum(len(r.out_tokens) for r in done)
    if lats:
        print(f"completed {len(done)}; {toks} tokens; "
              f"latency p50={statistics.median(lats)*1e3:.0f}ms "
              f"max={max(lats)*1e3:.0f}ms; "
              f"queue wait mean={sched.stats.mean_queue_wait_s()*1e3:.0f}ms")
    else:
        print("completed 0 (all requests shed past their deadline)")
    print(f"engine metrics: {eng.metrics}")
    if args.slo_ms:
        print(f"SLO: hits={sched.stats.slo_hits} "
              f"misses={sched.stats.slo_misses} shed={sched.stats.shed} "
              f"rejected={sched.stats.rejected}")
    for r in done[:3]:
        print(f"  req {r.rid}: out={r.out_tokens}")
    assert len(done) + sched.stats.shed + sched.stats.rejected \
        == args.requests
    if tracer is not None:
        n = tracer.write_chrome_trace(args.trace_out)
        print(f"trace: {n} events -> {args.trace_out}"
              + (f" ({tracer.dropped} dropped)" if tracer.dropped else ""))
    print("OK")


if __name__ == "__main__":
    main()
