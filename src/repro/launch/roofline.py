"""Roofline terms from dry-run artifacts (deliverable g).

Reads experiments/dryrun/<arch>__<shape>__<mesh>.json (written by
``repro.launch.dryrun``) and derives, per (arch, shape, mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(The dry-run's ``hlo_analysis`` reports per-device numbers from the SPMD
partitioned module, so no further division by chip count is needed.)

MODEL_FLOPS follows the assignment: 6*N*D for training (fwd+bwd),
2*N*D for inference steps, with N = active params (MoE: top-k only) and
D = tokens processed by the step. The ratio MODEL_FLOPS / total_HLO_FLOPs
exposes remat recompute and redundant work.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (constants from the assignment).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    kind: str
    n_devices: int
    n_params: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    collective_bytes: dict
    peak_gib: float          # TPU estimate (CPU dual-dtype twin deducted)
    peak_raw_gib: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs — <1 means remat/redundancy."""
        return self.model_flops / max(self.hlo_flops_total, 1.0)

    @property
    def static_gib(self) -> float:
        """Unavoidable per-device bytes: weights (+ optimizer state when
        training), perfectly sharded. If this alone exceeds HBM, the
        (arch, shape, mesh) is capacity-infeasible — no sharding fix."""
        n = {"train": 10.0}.get(self.kind, 2.0)    # bf16 w + f32 mu,nu
        return self.n_params * n / self.n_devices / 2**30

    def feasible(self, hbm_gib: float = 16.0) -> bool:
        return self.static_gib <= hbm_gib

    @property
    def mfu_upper_bound(self) -> float:
        """If the step ran exactly at its roofline bound, what MFU would
        the *useful* model flops achieve? (compute-bound & no waste = 1)"""
        ideal = self.model_flops / (self.n_devices * PEAK_FLOPS)
        return ideal / max(self.bound_s, 1e-30)


def model_flops(rec: dict) -> float:
    n_active = rec["n_active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_active * tokens
    # decode: one token per sequence against the cache
    return 2.0 * n_active * rec["global_batch"]


def from_record(rec: dict) -> Roofline:
    hlo = rec["hlo"]
    # TPU-corrected collective traffic when the dry-run recorded it
    # (bf16 width + RS-pattern rewrite; hlo_analysis docstring)
    coll = sum(hlo.get("collective_bytes_tpu",
                       hlo["collective_bytes"]).values())
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec["kind"], n_devices=rec["n_devices"],
        n_params=rec["n_params"],
        compute_s=hlo["flops_per_device"] / PEAK_FLOPS,
        memory_s=hlo["hbm_bytes_per_device"] / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops=model_flops(rec),
        hlo_flops_total=hlo["flops_per_device"] * rec["n_devices"],
        collective_bytes=hlo["collective_bytes"],
        peak_gib=rec["memory"].get(
            "peak_bytes_tpu_estimate",
            rec["memory"]["peak_bytes_per_device"]) / 2**30,
        peak_raw_gib=rec["memory"]["peak_bytes_per_device"] / 2**30,
    )


def load(arch: str, shape: str, mesh: str = "single",
         results_dir: Path | None = None) -> Roofline:
    p = (results_dir or RESULTS_DIR) / f"{arch}__{shape}__{mesh}.json"
    rec = json.loads(p.read_text())
    if rec.get("status") != "ok":
        raise ValueError(f"{p.name}: dry-run failed: {rec.get('error')}")
    return from_record(rec)


def load_all(mesh: str = "single", results_dir: Path | None = None):
    out = []
    rd = results_dir or RESULTS_DIR
    for p in sorted(rd.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "ok":
            out.append(from_record(rec))
    return out


def markdown_table(rows: list) -> str:
    head = ("arch | shape | kind | compute (s) | memory (s) | collective (s)"
            " | dominant | peak GiB/dev | useful-FLOPs ratio | MFU bound")
    lines = [head, " | ".join(["---"] * 10)]
    for r in rows:
        lines.append(
            f"{r.arch} | {r.shape} | {r.kind} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** | "
            f"{r.peak_gib:.2f} | {r.useful_flops_ratio:.2f} | "
            f"{r.mfu_upper_bound:.2f}")
    return "\n".join(lines)
