import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (jax locks the
# device count on first init), which is why the docstring sits below them
# and `from __future__` is not used in this module.

DOC = """Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, record memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Results cache to experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline benchmark reads them. This module (and ONLY this module) forces
512 host platform devices — smoke tests and benches see 1 device.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, get_shape
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.sharding.rules import ParallelPlan
from repro.train import optimizer as opt

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

LONG_CONTEXT_WINDOW = 4096  # sliding window for full-attention archs @500k


def config_for(arch: str, shape_name: str):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape_name == "long_500k" and cfg.uses_attention and \
            not cfg.sliding_window:
        # full attention is quadratic-infeasible at 524k: use the
        # sliding-window serving variant (DESIGN.md §5)
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg, shape


def build_lowering(arch: str, shape_name: str, mesh, sharding_overrides=None):
    """Returns (lowered, meta) for the (arch, shape) pair on mesh."""
    cfg, shape = config_for(arch, shape_name)
    model = build_model(cfg)
    plan = ParallelPlan.make(mesh, cfg, shape.kind)
    if sharding_overrides:
        plan = sharding_overrides(plan)

    params_s = jax.eval_shape(model.init, jax.random.key(0))
    p_sh = plan.param_shardings(params_s)
    specs = model.input_specs(shape)
    in_sh = plan.input_shardings(specs)

    if shape.kind == "train":
        oc = opt.AdamWConfig()
        opt_s = jax.eval_shape(opt.init_state, params_s)
        o_sh = plan.param_shardings(opt_s)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                loss, metrics = model.train_loss(p, batch, plan)
                return loss, metrics
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_p, new_s, om = opt.apply_updates(params, grads, opt_state, oc)
            metrics.update(om)
            return new_p, new_s, metrics

        fn = jax.jit(train_step,
                     in_shardings=(p_sh, o_sh, in_sh["batch"]),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))
        args = (params_s, opt_s, specs["batch"])
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch, plan)

        fn = jax.jit(prefill_step, in_shardings=(p_sh, in_sh["batch"]))
        args = (params_s, specs["batch"])
    else:  # decode: one token against a seq_len cache
        def serve_step(params, token, cache, cache_len):
            return model.decode_step(params, token, cache, cache_len, plan)

        fn = jax.jit(
            serve_step,
            in_shardings=(p_sh, in_sh["token"], in_sh["cache"],
                          plan.ns(jax.sharding.PartitionSpec())),
            out_shardings=(None, in_sh["cache"]),
            donate_argnums=(2,))
        specs_cl = jax.ShapeDtypeStruct((), jnp.int32)
        args = (params_s, specs["token"], specs["cache"], specs_cl)

    n_devices = mesh.size
    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "n_devices": n_devices,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "moe_mode": plan.moe_mode,
    }
    return fn.lower(*args), meta


def run_pair(arch: str, shape_name: str, mesh_kind: str, force=False) -> dict:
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_kind}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        lowered, meta = build_lowering(arch, shape_name, mesh)
        rec.update(meta)
        t1 = time.time()
        compiled = lowered.compile()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + max(mem.output_size_in_bytes - mem.alias_size_in_bytes, 0))
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": peak,
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
        txt = compiled.as_text()
        # CPU backend carries f32 twins of large bf16 loop state (no
        # native bf16 dot on CPU) and converts between them every
        # iteration; a TPU backend does neither. Deduct both the twin's
        # residency and its maintenance traffic (documented estimate).
        artifact, art_dims = hlo_analysis.dual_dtype_loop_state(txt)
        rec["memory"]["dual_dtype_artifact_bytes"] = artifact
        rec["memory"]["peak_bytes_tpu_estimate"] = peak - artifact
        stats = hlo_analysis.analyze(txt, exclude_dims=art_dims)
        rec["hlo"] = {
            "flops_per_device": stats.flops,
            "hbm_bytes_per_device": stats.hbm_bytes,
            "collective_bytes": dict(stats.collective_bytes),
            "collective_bytes_tpu": dict(stats.collective_bytes_tpu),
            "collective_counts": dict(stats.collective_counts),
            "loops": stats.loops[:32],
            "hlo_chars": len(txt),
        }
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    out_path.write_text(json.dumps(rec, indent=1))
    jax.clear_caches()  # keep the long --all sweep's RSS bounded
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    n_ok = n_err = 0
    for mk in meshes:
        for arch in archs:
            for shp in shapes:
                rec = run_pair(arch, shp, mk, force=args.force)
                ok = rec["status"] == "ok"
                n_ok += ok
                n_err += (not ok)
                msg = (f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                       f"flops={rec['hlo']['flops_per_device']:.3g} "
                       f"coll={sum(rec['hlo']['collective_bytes'].values()):.3g}B"
                       if ok else rec.get("error", "?"))
                print(f"[{rec['status']:5s}] {arch:18s} {shp:12s} {mk:6s} "
                      f"({rec['total_s']:6.1f}s) {msg}", flush=True)
    print(f"done: {n_ok} ok, {n_err} failed")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
