"""Multiplicity-aware analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` visits every while body ONCE (verified on this
jax build: a 10-step scan of 128^3 matmuls reports 1x matmul flops), so for
scanned-layer models it undercounts by ~n_layers. This parser walks the HLO
text, recovers loop trip counts from the loop-condition's comparison
constant, and accumulates per-device:

  * flops            — dot/convolution flops x enclosing trip counts
  * hbm_bytes        — operand+result bytes of top-level (fusion-boundary)
                       ops x trip counts (fusion bodies are not re-counted)
  * collective_bytes — per collective kind (all-reduce, all-gather,
                       reduce-scatter, all-to-all, collective-permute),
                       max(result, operands) bytes x trip counts

Shapes in the partitioned module are per-device, so every number here is
per-device already.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    operands: list
    attrs: str
    inner: str = ""   # raw text inside the op's parens (constants etc.)


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symtab: dict = field(default_factory=dict)   # op name -> result type


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name, rtype, kind = om.groups()
        # operand names: inside the call parens, before attribute list
        paren = line[line.index(kind + "(") + len(kind) + 1:]
        depth, i = 1, 0
        while i < len(paren) and depth:
            if paren[i] == "(":
                depth += 1
            elif paren[i] == ")":
                depth -= 1
            i += 1
        inner, attrs = paren[: i - 1], paren[i:]
        operands = _OPERAND_RE.findall(inner)
        cur.ops.append(Op(name, kind, rtype, operands, attrs, inner))
        cur.symtab[name] = rtype
    return comps


def _trip_count(while_op: Op, comps: dict) -> int:
    """Trip count from the while op's backend_config (XLA records
    known_trip_count), falling back to the largest integer constant in the
    loop condition computation."""
    m = re.search(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"', while_op.attrs)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w.\-]+)", while_op.attrs)
    best = 1
    if cm and cm.group(1) in comps:
        for op in comps[cm.group(1)].ops:
            if op.kind == "constant":
                f = re.fullmatch(r"\d+", op.inner.strip())
                if f:
                    best = max(best, int(f.group(0)))
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    out = 1
    for dt, dims in _SHAPE_RE.findall(op.result_type):
        if dt in DTYPE_BYTES:
            for d in dims.split(","):
                if d:
                    out *= int(d)
            break
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1
    if m and op.operands:
        lhs_type = comp.symtab.get(op.operands[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
    return 2.0 * out * contract


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: defaultdict(float))
    collective_counts: dict = field(default_factory=lambda: defaultdict(int))
    # TPU-corrected collective traffic (see ``analyze`` docstring):
    # f32 collectives counted at bf16 width, AR+slice counted as RS.
    collective_bytes_tpu: dict = field(
        default_factory=lambda: defaultdict(float))
    loops: list = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def total_collective_bytes_tpu(self) -> float:
        return sum(self.collective_bytes_tpu.values())


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "fusion",
               "custom-call", "after-all", "partition-id", "replica-id"}

# Ops that touch only a REGION of their big operand: counting the full
# operand would overstate HBM traffic by the trip count when they sit in
# a scan (rwkv/ssm time loops, KV-cache updates). Traffic model:
#   dynamic-slice / gather      -> read  = result bytes
#   dynamic-update-slice        -> read+write = 2 x update-operand bytes
#                                  (the buffer itself aliases in place)
_SLICING_READS = {"dynamic-slice", "gather"}


def _op_hbm_bytes(op: Op, comp: Computation) -> float:
    """Approximate HBM traffic of one op (read + write)."""
    rb = shape_bytes(op.result_type)
    if op.kind in _SLICING_READS:
        idx = sum(shape_bytes(comp.symtab.get(o, ""))
                  for o in op.operands[1:])          # indices are tiny
        return 2.0 * rb + idx
    if op.kind == "dynamic-update-slice":
        ub = shape_bytes(comp.symtab.get(op.operands[1], "")) \
            if len(op.operands) > 1 else rb
        return 2.0 * ub
    ob = sum(shape_bytes(comp.symtab.get(o, "")) for o in op.operands)
    return rb + ob


def _param_indices(comp: Computation) -> dict:
    """parameter name -> index for a fusion body computation."""
    out = {}
    for op in comp.ops:
        if op.kind == "parameter":
            m = re.fullmatch(r"(\d+)", op.inner.strip())
            if m:
                out[op.name] = int(m.group(1))
    return out


def _fusion_hbm_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """HBM traffic of a fusion at its boundary, crediting operands that
    are consumed only through slicing ops (region reads, not full reads)
    and in-place dynamic-update-slice roots (region writes)."""
    m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
    body = comps.get(m.group(1)) if m else None
    rb = shape_bytes(op.result_type)
    if body is None:
        return rb + sum(shape_bytes(comp.symtab.get(o, ""))
                        for o in op.operands)
    params = _param_indices(body)
    consumers: dict = {p: [] for p in params}
    for bop in body.ops:
        for o in bop.operands:
            if o in consumers:
                consumers[o].append(bop)
    total = 0.0
    for pname, idx in params.items():
        full = shape_bytes(body.symtab.get(pname, ""))
        cons = consumers[pname]
        if cons and all(c.kind in _SLICING_READS and c.operands
                        and c.operands[0] == pname for c in cons):
            total += min(full, sum(shape_bytes(c.result_type)
                                   for c in cons))
        elif cons and all(c.kind == "dynamic-update-slice" and c.operands
                          and c.operands[0] == pname for c in cons):
            total += min(full, sum(
                shape_bytes(body.symtab.get(c.operands[1], ""))
                for c in cons))
        else:
            total += full
    # in-place update root: the write is the update region, and the
    # buffer output aliases the input
    root = body.ops[-1] if body.ops else None
    if root is not None and root.kind == "dynamic-update-slice" \
            and len(root.operands) > 1:
        rb = min(rb, shape_bytes(body.symtab.get(root.operands[1], "")))
    return total + rb


def dual_dtype_loop_state(hlo: str, min_bytes: int = 2**26):
    """CPU-backend artifact detector: the CPU emitter has no native bf16
    dot, so XLA keeps an f32 twin of large bf16 loop-state buffers (e.g.
    a decode KV cache) in while-state, converting between the pair every
    iteration. A TPU backend consumes bf16 in the MXU directly and
    carries no twin. Returns (artifact_bytes, artifact_dims): the bytes
    of f32 while-state entries that shape-match a bf16 entry in the same
    state tuple (how much the CPU peak overstates the TPU peak), and the
    dim-strings of those twins (ops producing these shapes are twin
    maintenance — excludable from HBM-traffic accounting)."""
    artifact = 0
    dims_set: set[str] = set()
    for line in hlo.splitlines():
        m = re.search(r"=\s*(\(.*?\))\s*while\(", line)
        if not m:
            continue
        entries = _SHAPE_RE.findall(m.group(1))
        bf16_dims = {dims for dt, dims in entries if dt == "bf16"}
        for dt, dims in entries:
            if dt == "f32" and dims in bf16_dims:
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                if n * 4 >= min_bytes:
                    artifact += n * 4
                    dims_set.add(dims)
    # Weight/cache promotions: a `convert` producing a large f32 copy of
    # a same-shaped bf16 RESIDENT buffer — an entry parameter (weights,
    # KV cache) or a while-state entry. A TPU consumes bf16 directly and
    # materializes no twin. Each unique shape counted ONCE (residency
    # estimate, not traffic). Restricting to resident shapes avoids
    # deducting transient activation converts that never coexist.
    comps = parse_computations(hlo)
    resident: set[str] = set()
    entry = next((c for n, c in comps.items() if n.startswith("main")),
                 None)
    if entry is not None:
        for op in entry.ops:
            if op.kind == "parameter" and "bf16" in op.result_type:
                for dt, dims in _SHAPE_RE.findall(op.result_type):
                    if dt == "bf16":
                        resident.add(dims)
    for line in hlo.splitlines():
        m = re.search(r"=\s*(\(.*?\))\s*while\(", line)
        if m:
            for dt, dims in _SHAPE_RE.findall(m.group(1)):
                if dt == "bf16":
                    resident.add(dims)
    for comp in comps.values():
        for op in comp.ops:
            if op.kind != "convert" or not op.result_type.startswith("f32"):
                continue
            dims = _result_dims(op.result_type)
            if not dims or dims in dims_set or dims not in resident:
                continue
            operand_t = comp.symtab.get(op.operands[0], "") \
                if op.operands else ""
            if not operand_t.startswith("bf16") \
                    or _result_dims(operand_t) != dims:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            if n * 4 >= min_bytes:
                artifact += n * 4
                dims_set.add(dims)
    return artifact, dims_set


def dual_dtype_loop_state_bytes(hlo: str, min_bytes: int = 2**26) -> int:
    return dual_dtype_loop_state(hlo, min_bytes)[0]


def _result_dims(type_str: str) -> str:
    m = _SHAPE_RE.search(type_str)
    return m.group(2) if m else ""


_PASSTHROUGH = {"get-tuple-element", "tuple", "bitcast", "copy", "convert",
                "all-reduce-done", "optimization-barrier", "transpose",
                "reshape"}


def _all_consumers_slice(op: Op, comp: Computation) -> bool:
    """True if every (transitive, through pass-through ops) consumer of
    this op is a dynamic-slice — the all-reduce + shard-slice pattern
    that the TPU pipeline rewrites into a reduce-scatter."""
    if not hasattr(comp, "_consumers"):
        cons: dict = {}
        for o in comp.ops:
            for operand in o.operands:
                cons.setdefault(operand, []).append(o)
        comp._consumers = cons
    seen = set()

    def check(name: str) -> bool:
        if name in seen:
            return True
        seen.add(name)
        users = comp._consumers.get(name, [])
        if not users:
            return False                  # escapes the computation: unknown
        for u in users:
            if u.kind in ("dynamic-slice", "slice"):
                continue
            if u.kind in _PASSTHROUGH:
                if not check(u.name):
                    return False
                continue
            return False
        return True

    return check(op.name)


def _f32_fraction_as_bf16(type_str: str) -> float:
    """Bytes of the type with every f32 array counted at bf16 width,
    divided by its raw bytes (the CPU->TPU dtype-width correction)."""
    raw = corr = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        raw += n * DTYPE_BYTES[dt]
        corr += n * (2 if dt == "f32" else DTYPE_BYTES[dt])
    return corr / raw if raw else 1.0


def analyze(hlo: str, exclude_dims: set | None = None,
            bf16_target: bool = True) -> HloStats:
    """exclude_dims: result-dim strings whose producing ops are CPU
    dual-dtype twin maintenance (see ``dual_dtype_loop_state``) — their
    HBM traffic is excluded, since a TPU lowering would not perform it.

    ``collective_bytes_tpu`` additionally corrects two CPU-backend
    lowering artifacts (the raw numbers stay in ``collective_bytes``):
      * the CPU emitter promotes bf16 params/grads/activations to f32, so
        their collectives move 2x the bytes a bf16 TPU program would
        (disable with bf16_target=False for genuinely-f32 models);
      * XLA-CPU lacks the ReduceScatterCreator pass, so sharded-gradient
        reductions appear as all-reduce + dynamic-slice; a TPU lowering
        emits reduce-scatter (~half the ring traffic). Detected as an
        all-reduce whose every consumer is a (gte->)dynamic-slice.
    """
    exclude_dims = exclude_dims or set()
    comps = parse_computations(hlo)
    stats = HloStats()
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None:  # fall back: computation named main*
        entry = next((n for n in comps if n.startswith("main")), None)
    fused = set()
    for c in comps.values():
        for op in c.ops:
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if op.kind == "fusion" and m:
                fused.add(m.group(1))

    def visit(comp_name: str, mult: float, count_bytes: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                stats.flops += mult * _dot_flops(op, comp)
            if op.kind == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trips = _trip_count(op, comps)
                stats.loops.append((op.name, trips))
                if body:
                    visit(body.group(1), mult * trips, count_bytes)
                continue
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    visit(m.group(1), mult, False)   # flops only inside
            if op.kind in ("call", "conditional"):
                for m in re.finditer(r"(?:to_apply|branch_computations=\{?|"
                                     r"true_computation=|false_computation=)"
                                     r"%?([\w.\-]+)", op.attrs):
                    visit(m.group(1), mult, count_bytes)
            base = op.kind.split(".")[0]
            if base.endswith("-start"):
                base = base[:-6]
            if base in COLLECTIVES:
                rb = shape_bytes(op.result_type)
                ob = sum(shape_bytes(comp.symtab.get(o, ""))
                         for o in op.operands)
                # physical ICI traffic: ring all-reduce moves ~2x the
                # buffer (reduce-scatter + all-gather phases); AG/RS/A2A
                # move ~1x the full buffer; permute moves the buffer once
                factor = 2.0 if base == "all-reduce" else 1.0
                bytes_ = mult * max(rb, ob)
                stats.collective_bytes[base] += factor * bytes_
                stats.collective_counts[base] += 1
                tpu_factor = factor
                if base == "all-reduce" and _all_consumers_slice(op, comp):
                    tpu_factor = 1.0          # TPU lowers this as RS
                scale = _f32_fraction_as_bf16(op.result_type) \
                    if bf16_target else 1.0
                stats.collective_bytes_tpu[base] += \
                    tpu_factor * scale * bytes_
            if count_bytes and op.kind not in _SKIP_BYTES \
                    and _result_dims(op.result_type) not in exclude_dims:
                stats.hbm_bytes += mult * _op_hbm_bytes(op, comp)
        return

    def visit_fusion_boundary(comp_name: str, mult: float):
        """Count fusion ops' own operand/result bytes at the call site."""
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            if op.kind == "fusion":
                if _result_dims(op.result_type) in exclude_dims:
                    continue
                stats.hbm_bytes += mult * _fusion_hbm_bytes(op, comp, comps)
            elif op.kind == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trips = _trip_count(op, comps)
                if body:
                    visit_fusion_boundary(body.group(1), mult * trips)

    if entry:
        visit(entry, 1.0, True)
        visit_fusion_boundary(entry, 1.0)
    return stats
