"""Mixture-of-Experts FFN with capacity-based top-k dispatch.

Two distributed modes (picked by divisibility against the mesh ``model``
axis — see ``repro.sharding.rules``):

* **EP** (expert-parallel, kimi-k2: 384 experts / 16 = 24 per group):
  each model-axis group owns a contiguous expert slice; every group
  dispatches its *local tokens* to its *local experts* and the partial
  outputs are ``psum``-ed over the model axis.
* **TP** (expert-tensor-parallel, grok-1: 8 experts < 16 groups): every
  group holds all experts but only a ``d_ff / model`` slice; the expert
  contraction is partial over d_ff and ``psum``-ed.

The dispatch is sort-free: slot positions come from a one-hot prefix
count, so it lowers to cumsum + scatter (no dynamic shapes) and is
identical on a single device (E_loc = E, no psum) for smoke tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe(rng, cfg, dtype=None):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dtype = dtype or cfg.dtype
    ks = jax.random.split(rng, 4)

    def e_init(k, din, dout):
        return jax.vmap(lambda kk: layers.dense_init(kk, din, dout, dtype))(
            jax.random.split(k, E))

    p = {
        "router": layers.dense_init(ks[0], d, E, jnp.float32),
        "w_in": e_init(ks[1], d, f),
        "w_out": e_init(ks[2], f, d),
    }
    if cfg.act in layers.GATED_ACTS:
        p["w_gate"] = e_init(ks[3], d, f)
    return p


def capacity(n_tokens: int, cfg) -> int:
    """Static per-expert slot count for a local token block."""
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / max(cfg.n_experts, 1))
    return max(8, -(-c // 8) * 8)  # round up to 8


def route(x2d: jnp.ndarray, router: jnp.ndarray, cfg):
    """Returns (weights (T,k), ids (T,k), aux_loss scalar)."""
    logits = (x2d.astype(jnp.float32) @ router)             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], cfg.n_experts, dtype=jnp.float32), axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return w, ids, cfg.router_aux_weight * aux + 1e-3 * zloss


def dispatch_tables(ids, w, e0: int, E_loc: int, C: int):
    """Slot assignment for experts [e0, e0+E_loc).

    Returns (token_idx (E_loc, C) int32 in [0, T] where T = pad,
             gate_w (E_loc, C) f32).
    """
    T, k = ids.shape
    P = T * k
    pair_e = ids.reshape(P)
    pair_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    pair_w = w.reshape(P).astype(jnp.float32)

    le = pair_e - e0
    in_range = (le >= 0) & (le < E_loc)
    le = jnp.where(in_range, le, E_loc)                     # E_loc = dump row
    onehot = jax.nn.one_hot(le, E_loc + 1, dtype=jnp.int32)  # (P, E_loc+1)
    prefix = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(prefix * onehot, axis=-1)                  # (P,)
    keep = in_range & (pos < C)
    row = jnp.where(keep, le, E_loc)
    col = jnp.where(keep, pos, 0)
    tok = jnp.full((E_loc + 1, C), T, jnp.int32)
    tok = tok.at[row, col].set(jnp.where(keep, pair_t, T))
    gw = jnp.zeros((E_loc + 1, C), jnp.float32)
    gw = gw.at[row, col].set(jnp.where(keep, pair_w, 0.0))
    return tok[:E_loc], gw[:E_loc]


def expert_compute(g, p_experts, cfg, slice_f=None):
    """g: (E_loc, C, d) -> (E_loc, C, d) through each expert's FFN."""
    w_in, w_out = p_experts["w_in"], p_experts["w_out"]
    if "w_gate" in p_experts:
        h = layers.act_fn(cfg.act)(
            jnp.einsum("ecd,edf->ecf", g, p_experts["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", g, w_in)
    else:
        h = layers.act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", g, w_in))
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def moe_ffn_local(x2d, p, cfg, *, e0=0, E_loc=None, expert_slice=None):
    """Contribution of experts [e0, e0+E_loc) for local tokens x2d (T, d).

    expert_slice: optional fn selecting the local expert-weight block.
    Returns (out (T, d) — PARTIAL if E_loc < n_experts, aux_loss).
    """
    T, d = x2d.shape
    E_loc = cfg.n_experts if E_loc is None else E_loc
    C = capacity(T, cfg)
    w, ids, aux = route(x2d, p["router"], cfg)
    tok, gw = dispatch_tables(ids, w, e0, E_loc, C)
    xp = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    g = xp[tok]                                             # (E_loc, C, d)
    pe = {k_: v for k_, v in p.items() if k_ != "router"}
    if expert_slice is not None:
        pe = expert_slice(pe)
    elif E_loc < cfg.n_experts and \
            all(v.shape[0] == cfg.n_experts for v in pe.values()):
        # local API with a sub-range of experts: slice the weight block
        # (under shard_map the weights arrive pre-sliced instead)
        pe = {k_: v[e0:e0 + E_loc] for k_, v in pe.items()}
    y = expert_compute(g, pe, cfg)
    y = y * gw[..., None].astype(y.dtype)
    out = jnp.zeros((T + 1, d), y.dtype)
    out = out.at[tok].add(y)
    return out[:T].astype(x2d.dtype), aux


def _flat_index(axes) -> "jnp.ndarray":
    """Row-major device index over a tuple of mesh axes (inside shard_map)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def moe_decode_ffn(x, p, cfg, plan):
    """Weight-stationary MoE decode (§Perf, kimi-k2 x decode_32k).

    At decode the token batch is ~MBs while the expert weights are ~GBs
    per layer, so the train-mode pattern (all-gather the FSDP-sharded
    expert rows into the shard_map) moves 5 orders of magnitude more
    bytes than the tokens. Instead: keep the 2-D weight layout resident
    (EP: (E/model, d/fsdp, f); TP: (E, d/fsdp, f/model)), all-gather the
    TOKENS over the fsdp axes, contract partially, and psum the partial
    token outputs — fsdp for the d-contraction, model for the expert
    (EP) or f (TP) partials. Collective bytes per layer drop from the
    weight bytes to a few token-sized buffers.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    B, S, d = x.shape
    mesh, maxis = plan.mesh, plan.model_axis
    fsdp = tuple(a for a in (plan.weight_fsdp if isinstance(
        plan.weight_fsdp, tuple) else (plan.weight_fsdp,)) if a)
    n_model = plan.axis_size(maxis)
    n_fsdp = plan.axis_size(fsdp) if fsdp else 1
    E_loc = cfg.n_experts // n_model if plan.moe_mode == "ep" \
        else cfg.n_experts
    b_ax = plan._div(B, plan.batch_axes)
    d_loc = d // n_fsdp

    # weight specs mirroring rules.param_spec's moe branch
    if plan.moe_mode == "ep":
        wspec = {"router": P(None),
                 "w_in": P(maxis, fsdp or None, None),
                 "w_gate": P(maxis, fsdp or None, None),
                 "w_out": P(maxis, None, fsdp or None)}
    else:
        wspec = {"router": P(None),
                 "w_in": P(None, fsdp or None, maxis),
                 "w_gate": P(None, fsdp or None, maxis),
                 "w_out": P(None, maxis, fsdp or None)}
    wspec = {k_: wspec[k_] for k_ in p}

    b_axes = (b_ax,) if isinstance(b_ax, str) else tuple(b_ax or ())

    def body(xb, pb):
        # ---- gather all tokens (tiny at decode) to every device
        if b_axes:
            xg = jax.lax.all_gather(xb, b_axes, axis=0, tiled=True)
        else:
            xg = xb
        T = xg.shape[0] * xg.shape[1]
        x2d = xg.reshape(T, d)
        w, ids, aux = route(x2d, pb["router"], cfg)      # replicated compute
        e0 = jax.lax.axis_index(maxis) * E_loc if plan.moe_mode == "ep" \
            else 0
        C = capacity(T, cfg)
        tok, gw = dispatch_tables(ids, w, e0, E_loc, C)
        xp = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
        g = xp[tok]                                      # (E_loc, C, d)
        # ---- partial contraction over this device's d rows
        i_f = _flat_index(fsdp) if fsdp else jnp.zeros((), jnp.int32)
        g_loc = jax.lax.dynamic_slice_in_dim(g, i_f * d_loc, d_loc, axis=2)
        w_in, w_out = pb["w_in"], pb["w_out"]
        h = jnp.einsum("ecd,edf->ecf", g_loc, w_in)
        if "w_gate" in pb:
            hg = jnp.einsum("ecd,edf->ecf", g_loc, pb["w_gate"])
            if fsdp:
                h = jax.lax.psum(h, fsdp)
                hg = jax.lax.psum(hg, fsdp)
            h = layers.act_fn(cfg.act)(hg) * h
        else:
            if fsdp:
                h = jax.lax.psum(h, fsdp)
            h = layers.act_fn(cfg.act)(h)
        y = jnp.einsum("ecf,efd->ecd", h, w_out)         # (E_loc, C, d_loc)
        y = y * gw[..., None].astype(y.dtype)
        out = jnp.zeros((T + 1, d_loc), y.dtype)
        out = out.at[tok].add(y)
        out = out[:T]
        # EP: expert partials; TP: f partials — both close over model
        out = jax.lax.psum(out, maxis)
        if fsdp:                                         # reassemble d
            out = jax.lax.all_gather(out, fsdp, axis=1, tiled=True)
        out = out.reshape(xg.shape[0], S, d).astype(x.dtype)
        if b_axes:                                       # back to my batch
            i_b = _flat_index(b_axes)
            out = jax.lax.dynamic_slice_in_dim(
                out, i_b * xb.shape[0], xb.shape[0], axis=0)
        aux = jax.lax.pmean(aux, b_axes + (maxis,))
        return out, aux

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(b_ax, None, None), wspec),
                   out_specs=(P(b_ax, None, None), P()),
                   check_rep=False)
    return fn(x, p)


def moe_ffn(x, p, cfg, plan=None):
    """x: (B, S, d). plan: repro.sharding.rules.ParallelPlan or None.

    Returns (out (B,S,d), aux_loss scalar).
    """
    B, S, d = x.shape
    if plan is None or plan.mesh is None or not plan.moe_mode:
        out, aux = moe_ffn_local(x.reshape(B * S, d), p, cfg)
        return out.reshape(B, S, d), aux

    if plan.kind == "decode":
        return moe_decode_ffn(x, p, cfg, plan)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = plan.mesh
    maxis = plan.model_axis               # "model"
    n_model = plan.axis_size(maxis)
    mode = plan.moe_mode                  # "ep" | "tp"
    # batch dim replicates when not divisible (e.g. decode with B=1)
    b_ax = plan._div(B, plan.batch_axes)
    batch_axes = (b_ax,) if isinstance(b_ax, str) else (b_ax or ())

    xspec = P(b_ax, None, None)
    if mode == "ep":
        E_loc = cfg.n_experts // n_model
        wspec = {k_: (P(None) if k_ == "router" else P(maxis, None, None))
                 for k_ in p}

        def body(xb, pb):
            i = jax.lax.axis_index(maxis)
            Bb, Sb, _ = xb.shape
            out, aux = moe_ffn_local(xb.reshape(Bb * Sb, d), pb, cfg,
                                     e0=i * E_loc, E_loc=E_loc)
            out = jax.lax.psum(out, maxis)
            aux = jax.lax.pmean(aux, batch_axes + (maxis,))
            return out.reshape(Bb, Sb, d), aux
    else:  # tp: all experts, d_ff sliced over model axis
        wspec = {k_: (P(None) if k_ == "router" else P(None, None, maxis))
                 for k_ in p}
        wspec["w_out"] = P(None, maxis, None)

        def body(xb, pb):
            Bb, Sb, _ = xb.shape
            out, aux = moe_ffn_local(xb.reshape(Bb * Sb, d), pb, cfg)
            out = jax.lax.psum(out, maxis)
            aux = jax.lax.pmean(aux, batch_axes + (maxis,))
            return out.reshape(Bb, Sb, d), aux

    wspec_tree = {k_: wspec[k_] for k_ in p}
    fn = shard_map(body, mesh=mesh,
                   in_specs=(xspec, wspec_tree),
                   out_specs=(xspec, P()),
                   check_rep=False)
    return fn(x, p)
