"""Bi-LSTM with hierarchically-refined Label Attention Network (LAN) —
the paper's NER model family [Cui & Zhang, arXiv:1908.08676] (§3.2.3).

Each layer: BiLSTM over the token sequence, then multi-head attention
where the *label embeddings* are keys/values; the label-aware summary is
concatenated to the BiLSTM output ("hierarchical refinement"). The LAST
layer's attention distribution (single head over labels) IS the
prediction — no CRF/softmax layer, which is the point of the paper's
model choice (Bi-LSTM(LAN) > Bi-LSTM(CRF/softmax) on long-range label
dependencies).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclass(frozen=True)
class LANConfig:
    vocab_size: int = 4096
    n_labels: int = 9
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    dtype: object = jnp.float32


# ------------------------------------------------------------------- LSTM
def init_lstm(rng, d_in: int, d_h: int, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "w": layers.dense_init(k1, d_in, 4 * d_h, dtype),
        "u": layers.dense_init(k2, d_h, 4 * d_h, dtype),
        "b": jnp.zeros((4 * d_h,), dtype),
    }


def lstm_scan(p, x, reverse: bool = False):
    """x (B, S, d_in) -> h (B, S, d_h)."""
    B, S, _ = x.shape
    d_h = p["u"].shape[0]

    def step(carry, x_t):
        h, c = carry
        z = x_t @ p["w"] + h @ p["u"] + p["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (jnp.zeros((B, d_h), x.dtype), jnp.zeros((B, d_h), x.dtype))
    xs = jnp.moveaxis(x, 1, 0)
    _, hs = jax.lax.scan(step, init, xs, reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def bilstm(p, x):
    fwd = lstm_scan(p["fwd"], x)
    bwd = lstm_scan(p["bwd"], x, reverse=True)
    return jnp.concatenate([fwd, bwd], axis=-1)       # (B, S, 2*d_h)


# ------------------------------------------------------------------- LAN
def label_attention(h, label_emb, p, n_heads: int):
    """h (B,S,d), label_emb (L,d) -> (attn_out (B,S,d), scores (B,S,L))."""
    B, S, d = h.shape
    L = label_emb.shape[0]
    hd = d // n_heads
    q = (h @ p["w_q"]).reshape(B, S, n_heads, hd)
    k = (label_emb @ p["w_k"]).reshape(L, n_heads, hd)
    v = (label_emb @ p["w_v"]).reshape(L, n_heads, hd)
    scores = jnp.einsum("bshd,lhd->bshl", q, k) / jnp.sqrt(float(hd))
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bshl,lhd->bshd", w, v).reshape(B, S, d)
    return out, jnp.mean(scores, axis=2)               # head-avg (B,S,L)


def init_lan_layer(rng, d_in: int, d_model: int, dtype):
    ks = jax.random.split(rng, 5)
    d_h = d_model // 2
    return {
        "fwd": init_lstm(ks[0], d_in, d_h, dtype),
        "bwd": init_lstm(ks[1], d_in, d_h, dtype),
        "w_q": layers.dense_init(ks[2], d_model, d_model, dtype),
        "w_k": layers.dense_init(ks[3], d_model, d_model, dtype),
        "w_v": layers.dense_init(ks[4], d_model, d_model, dtype),
    }


def init_params(rng, cfg: LANConfig):
    ks = jax.random.split(rng, cfg.n_layers + 2)
    lans = []
    d_in = cfg.d_model
    for i in range(cfg.n_layers):
        lans.append(init_lan_layer(ks[i], d_in, cfg.d_model, cfg.dtype))
        d_in = 2 * cfg.d_model      # [h ; label-attn] concat feeds next layer
    return {
        "embed": (jax.random.normal(ks[-2], (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(cfg.dtype),
        "label_embed": (jax.random.normal(ks[-1], (cfg.n_labels, cfg.d_model))
                        * 0.02).astype(cfg.dtype),
        "lan_layers": lans,
    }


def forward(params, cfg: LANConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B,S) -> per-token label logits (B,S,n_labels)."""
    x = params["embed"][tokens]
    scores = None
    for i, lp in enumerate(params["lan_layers"]):
        h = bilstm(lp, x)                              # (B,S,d_model)
        attn, scores = label_attention(h, params["label_embed"], lp,
                                       cfg.n_heads)
        x = jnp.concatenate([h, attn], axis=-1)
    return scores                                       # last layer scores


def loss(params, cfg: LANConfig, tokens, labels, mask=None):
    logits = forward(params, cfg, tokens)
    return layers.softmax_xent(logits, labels, mask)


def predict(params, cfg: LANConfig, tokens):
    return jnp.argmax(forward(params, cfg, tokens), axis=-1)
