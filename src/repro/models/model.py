"""Model factory: one composable entry point for every assigned arch.

``build_model(cfg)`` returns a ``Model`` with pure functions:
    init(rng)                                   -> params
    train_loss(params, batch, plan)             -> (loss, metrics)
    prefill(params, batch, plan)                -> (logits_last, cache)
    decode_step(params, token, cache, cache_len, plan) -> (logits, cache)
    init_cache(batch_size, cache_capacity)      -> zeroed cache pytree
    input_specs(shape)                          -> ShapeDtypeStruct batch

Batch dicts:
    train:   {"tokens": (B, S+1) int32 [, "patch_embeds" | "frames"]}
    prefill: {"tokens": (B, S) int32 [, "patch_embeds" | "frames"]}
    decode:  token (B, 1) int32 + cache + cache_len (existing token count)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, layers, transformer

GRID = 16  # stub vision patch grid side (n_patches = GRID*GRID when 256)


# ===================================================================== init
def init_params(rng, cfg):
    d, dtype = cfg.d_model, cfg.dtype
    ks = jax.random.split(rng, 8)
    kind = transformer.block_kind(cfg)
    vp = padded_vocab(cfg)
    p: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (vp, d), jnp.float32)
                  * 0.02).astype(dtype),
        "blocks": transformer.init_stack(ks[1], cfg, cfg.n_layers, kind),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(ks[2], d, vp, dtype)
    # rope == "learned" (whisper) uses computed sinusoidal positions — no
    # table, so the 32k/500k serving shapes need no max-length carve-out.
    if cfg.encoder_layers:
        p["encoder"] = {
            "blocks": transformer.init_stack(ks[4], cfg, cfg.encoder_layers,
                                             "dense"),
            "final_norm": jnp.ones((d,), dtype),
            "pos_embed": (jax.random.normal(ks[5], (cfg.n_frames, d),
                                            jnp.float32) * 0.02).astype(dtype),
        }
    return p


# ================================================================ embedding
def _embed_tokens(p, cfg, tokens):
    return p["embed"][tokens]


def _mrope_positions(B, n_patches, s_text):
    """Static M-RoPE position ids (B, 3, P + s_text) for one leading image."""
    g = max(int(n_patches ** 0.5), 1)
    pi = jnp.arange(n_patches)
    patch = jnp.stack([jnp.zeros_like(pi), pi // g, pi % g])      # (3, P)
    t0 = g  # text starts after the grid extent
    ti = jnp.arange(s_text) + t0
    text = jnp.stack([ti, ti, ti])                                 # (3, S)
    pos = jnp.concatenate([patch, text], axis=1)                   # (3, P+S)
    return jnp.broadcast_to(pos[None], (B, 3, pos.shape[1])).astype(jnp.int32)


def _build_inputs(p, cfg, batch, *, drop_last_token: bool):
    """Returns (x (B,S,d), extras, label_offset) for train/prefill."""
    tokens = batch["tokens"]
    if drop_last_token:
        tokens = tokens[:, :-1]
    B, S_text = tokens.shape
    extras: dict[str, Any] = {}
    prefix = 0
    if cfg.frontend == "vision":
        pe = batch["patch_embeds"].astype(cfg.dtype)               # (B,P,d)
        x = jnp.concatenate([pe, _embed_tokens(p, cfg, tokens)], axis=1)
        prefix = pe.shape[1]
        extras["mrope_positions"] = _mrope_positions(B, prefix, S_text)
    else:
        x = _embed_tokens(p, cfg, tokens)
        if cfg.rope == "learned":
            x = x + layers.sinusoidal_pos(jnp.arange(x.shape[1]),
                                          cfg.d_model, x.dtype)[None]
    if cfg.frontend == "audio":
        enc = _run_encoder(p, cfg, batch["frames"].astype(cfg.dtype))
        # precompute per-layer cross K/V from encoder output
        xkv = jax.vmap(lambda bp: attention.encode_cross_kv(enc, bp["xattn"],
                                                            cfg))(p["blocks"])
        extras["enc_kv_stack"] = xkv                                # (L,B,T,H,hd)
    return x, extras, prefix


def _run_encoder(p, cfg, frames):
    e = p["encoder"]
    x = frames + e["pos_embed"][None, : frames.shape[1], :]

    def body(h, bp):
        hh = layers.rmsnorm(h, bp["ln1"], cfg.norm_eps)
        o, _ = attention.attention_block(hh, bp["attn"], cfg, mode="train",
                                         causal=False, sliding_window=0)
        h = h + o
        hh = layers.rmsnorm(h, bp["ln2"], cfg.norm_eps)
        return h + layers.mlp(hh, bp["ffn"], cfg.act), None

    x, _ = jax.lax.scan(body, x, e["blocks"])
    return layers.rmsnorm(x, e["final_norm"], cfg.norm_eps)


def padded_vocab(cfg) -> int:
    """Embedding/head rows padded to a multiple of 128 (shardable over
    any mesh axis <=128; whisper's 51865 and hymba's 32001 otherwise
    force replicated logits — 18 GiB/device at train_4k, §Perf)."""
    return -(-cfg.vocab_size // 128) * 128


def _logits(p, cfg, x):
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    out = x @ head
    vp = head.shape[-1]
    if vp != cfg.vocab_size:          # mask padded ids, keep sharding
        pad_mask = jnp.arange(vp) >= cfg.vocab_size
        out = jnp.where(pad_mask, jnp.asarray(-1e9, out.dtype), out)
    return out


# ============================================================ stack wrapper
def _run_stack(p, cfg, x, *, mode, cache, extras, plan):
    kind = transformer.block_kind(cfg)
    if kind == "decoder_x":
        # cross K/V is a per-layer scanned input
        enc_kv_stack = (extras or {}).pop("enc_kv_stack", None)
        if enc_kv_stack is None and cache is not None:
            enc_kv_stack = {"k": cache.pop("xk"), "v": cache.pop("xv")}

        def body(h, xs):
            bp, c, ekv = xs
            ex = dict(extras or {})
            ex["enc_kv"] = ekv
            h, new_c, aux = transformer.apply_block(
                h, bp, cfg, kind=kind, mode=mode, cache=c, extras=ex,
                plan=plan)
            return h, (new_c, aux)

        fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
        x, (new_cache, aux) = jax.lax.scan(fn, x, (p["blocks"], cache,
                                                   enc_kv_stack))
        if new_cache is not None and mode != "train":
            new_cache["xk"] = enc_kv_stack["k"]
            new_cache["xv"] = enc_kv_stack["v"]
        return x, new_cache, jnp.sum(aux)
    return transformer.apply_stack(x, p["blocks"], cfg, kind=kind, mode=mode,
                                   cache=cache, extras=extras, plan=plan)


# ===================================================================== model
@dataclass(frozen=True)
class Model:
    cfg: Any

    # ---------------- init ----------------
    def init(self, rng):
        return init_params(rng, self.cfg)

    # ---------------- train ----------------
    def train_loss(self, params, batch, plan=None):
        cfg = self.cfg
        x, extras, prefix = _build_inputs(params, cfg, batch,
                                          drop_last_token=True)
        if plan is not None:
            x = plan.constrain_act(x)
        x, _, aux = _run_stack(params, cfg, x, mode="train", cache=None,
                               extras=extras, plan=plan)
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if prefix:
            x = x[:, prefix:, :]
        logits = _logits(params, cfg, x)
        if plan is not None:
            logits = plan.constrain_logits(logits)
        labels = batch["tokens"][:, 1:]
        loss = layers.softmax_xent(logits, labels)
        total = loss + aux
        return total, {"xent": loss, "aux": aux}

    # ---------------- prefill ----------------
    def prefill(self, params, batch, plan=None, *, last_idx=None,
                cache=None, cache_len=None, block_table=None,
                paged_kernel: bool = False, n_write=None):
        """last_idx: optional (B,) int32 — per-row index of the last *real*
        token when rows are right-padded to a shared bucket length (the
        serving engine's batched mixed-length admission). None keeps the
        unpadded behaviour: logits at the final position.

        **Chunked mode** (``cache`` is not None): ``batch["tokens"]``
        (B, S) is a **chunk window** of each row's prompt at start
        offset ``cache_len[b]`` — K/V written into the *resident* cache
        at positions ``cache_len[b] + [0, S)``, each query attending
        causally to everything already resident plus the window's own
        prefix. This is the same multi-token decode path the speculative
        :meth:`verify_step` uses (and inherits its proven differential
        property: position j's logits equal what the j+1-th of S
        sequential :meth:`decode_step` calls would produce), so a prompt
        split into chunk windows reproduces a monolithic prefill
        bit-for-bit. ``block_table``/``paged_kernel``/``n_write`` follow
        :meth:`verify_step` (paged rows divert writes past their granted
        count to the scratch block). Returns (logits (B, S, V), cache).
        Recurrent families (rwkv / hybrid SSM) cannot chunk — their
        state steps token-at-a-time — and raise, like verify. With
        ``last_idx`` set in chunked mode, only each row's last-real-
        position logits are computed (returned as (B, 1, V)) — a chunk
        caller samples at most one token per row, so projecting the
        whole window against the vocabulary would be pure waste."""
        if cache is not None:
            x, new_cache = self._window(params, batch["tokens"], cache,
                                        cache_len, plan, block_table,
                                        paged_kernel, n_write)
            if last_idx is not None:
                idx = jnp.asarray(last_idx, jnp.int32)
                x = x[jnp.arange(x.shape[0]), idx][:, None, :]
            return _logits(params, self.cfg, x), new_cache
        cfg = self.cfg
        x, extras, prefix = _build_inputs(params, cfg, batch,
                                          drop_last_token=False)
        if plan is not None:
            x = plan.constrain_act(x)
        x, cache, _ = _run_stack(params, cfg, x, mode="prefill", cache=None,
                                 extras=extras, plan=plan)
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if last_idx is None:
            x_last = x[:, -1:, :]
        else:
            idx = jnp.asarray(last_idx, jnp.int32) + prefix
            x_last = x[jnp.arange(x.shape[0]), idx][:, None, :]
        logits = _logits(params, cfg, x_last)
        return logits, cache

    # ---------------- decode ----------------
    def decode_step(self, params, token, cache, cache_len, plan=None,
                    block_table=None, paged_kernel: bool = False):
        """token (B,1) int32; cache_len = existing token count — a scalar
        (all rows at one length) or a (B,) vector (per-slot lengths for
        mixed-length continuous batching); the new token is written at
        index cache_len (per row when a vector).

        block_table: optional (B, max_blocks) int32 — paged-KV mode. The
        cache leaves are then a shared block pool (L, num_blocks,
        block_size, Hkv, hd) and row b's logical position j resolves to
        (block_table[b, j // block_size], j % block_size). Requires a
        (B,) cache_len vector. ``paged_kernel`` switches the paged read
        from the transient jnp gather to the in-place Pallas kernel
        (``kernels.paged_attention``; interpret mode off-TPU)."""
        cfg = self.cfg
        B = token.shape[0]
        x = _embed_tokens(params, cfg, token)
        extras = {"cache_len": cache_len}
        if block_table is not None:
            extras["block_table"] = jnp.asarray(block_table, jnp.int32)
            extras["paged_kernel"] = bool(paged_kernel)
        if cfg.rope == "learned":
            x = x + layers.sinusoidal_pos(
                jnp.reshape(cache_len, (-1, 1)), cfg.d_model, x.dtype)
        if cfg.rope == "mrope":
            pos = jnp.reshape(jnp.broadcast_to(
                jnp.asarray(cache_len, jnp.int32), (B,)), (B, 1, 1))
            extras["mrope_positions"] = jnp.broadcast_to(pos, (B, 3, 1))
        if plan is not None:
            x = plan.constrain_act(x)
        x, new_cache, _ = _run_stack(params, cfg, x, mode="decode",
                                     cache=cache, extras=extras, plan=plan)
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = _logits(params, cfg, x)
        return logits, new_cache

    # ---------------- verify (speculative multi-token decode) ----------
    def verify_step(self, params, tokens, cache, cache_len, plan=None,
                    block_table=None, paged_kernel: bool = False,
                    n_write=None):
        """Multi-token decode: the speculative **verify** path — and,
        via :meth:`prefill`'s chunked mode, the **chunk-window** prompt
        ingestion path (a chunk of prompt tokens is a verify window
        whose tokens happen to be known-correct).

        tokens (B, S) int32 — row b's S = k+1 window tokens (the last
        committed token followed by the draft's proposals) at positions
        ``cache_len[b] + [0, S)``; cache_len (B,) int32 tokens already
        cached per row. Every window token writes its K/V at its own
        position and attends causally *inside the window* (query j sees
        cache positions <= cache_len[b] + j), so ``logits[:, j]`` equals
        what the j+1-th of S sequential :meth:`decode_step` calls would
        produce — the differential property ``tests/test_speculative.py``
        enforces. Returns (logits (B, S, V), new_cache).

        Paged mode (``block_table``): ``n_write`` (B,) caps how many
        window positions row b may scatter into its own blocks; writes
        past the cap land in the scratch block (a speculating row is
        granted blocks up to its watermark *before* the step — see
        ``ServingEngine._ensure_writable`` — and a rider row must not
        touch blocks it does not own). Only pure-attention ``{k, v}``
        caches verify: recurrent state (rwkv / hybrid SSM) advances
        token-at-a-time and has no multi-token catch-up here.
        """
        x, new_cache = self._window(params, tokens, cache, cache_len,
                                    plan, block_table, paged_kernel,
                                    n_write)
        logits = _logits(params, self.cfg, x)
        return logits, new_cache

    def _window(self, params, tokens, cache, cache_len, plan,
                block_table, paged_kernel, n_write):
        """Shared multi-token window body (verify / chunked prefill):
        runs the decode-mode stack over S tokens per row at positions
        ``cache_len[b] + [0, S)`` and returns the final-norm hidden
        states (B, S, d) plus the updated cache — the caller decides
        which positions to project against the vocabulary."""
        cfg = self.cfg
        kind = transformer.block_kind(cfg)
        if kind in ("rwkv", "hybrid"):
            raise ValueError(f"multi-token window unsupported for family "
                             f"{kind!r} (recurrent state is sequential)")
        B, S = tokens.shape
        x = _embed_tokens(params, cfg, tokens)
        idx = jnp.reshape(jnp.asarray(cache_len, jnp.int32), (-1,))
        extras = {"cache_len": idx}
        if block_table is not None:
            extras["block_table"] = jnp.asarray(block_table, jnp.int32)
            extras["paged_kernel"] = bool(paged_kernel)
            if n_write is not None:
                extras["n_write"] = jnp.asarray(n_write, jnp.int32)
        pos = idx[:, None] + jnp.arange(S)[None, :]
        if cfg.rope == "learned":
            x = x + layers.sinusoidal_pos(pos, cfg.d_model, x.dtype)
        if cfg.rope == "mrope":
            extras["mrope_positions"] = jnp.broadcast_to(
                pos[:, None, :], (B, 3, S)).astype(jnp.int32)
        if plan is not None:
            x = plan.constrain_act(x)
        x, new_cache, _ = _run_stack(params, cfg, x, mode="decode",
                                     cache=cache, extras=extras, plan=plan)
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, new_cache

    # ---------------- cache ----------------
    def init_cache(self, batch_size: int, capacity: int):
        """Zeroed decode cache with room for ``capacity`` tokens."""
        cfg = self.cfg
        L, B = cfg.n_layers, batch_size
        H, Hkv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
        kind = transformer.block_kind(cfg)
        if kind == "rwkv":
            return {
                "state": jnp.zeros((L, B, H, hd, hd), jnp.float32),
                "last_x_t": jnp.zeros((L, B, d), cfg.dtype),
                "last_x_c": jnp.zeros((L, B, d), cfg.dtype),
            }
        cache = {
            "k": jnp.zeros((L, B, capacity, Hkv, hd), cfg.dtype),
            "v": jnp.zeros((L, B, capacity, Hkv, hd), cfg.dtype),
        }
        if kind == "hybrid":
            cache["ssm_state"] = jnp.zeros((L, B, cfg.dinner,
                                            max(cfg.ssm_state, 1)), jnp.float32)
        if kind == "decoder_x":
            cache["xk"] = jnp.zeros((L, B, cfg.n_frames, Hkv, hd), cfg.dtype)
            cache["xv"] = jnp.zeros((L, B, cfg.n_frames, Hkv, hd), cfg.dtype)
        return cache

    def init_paged_cache(self, num_blocks: int, block_size: int):
        """Zeroed block-pool KV: ``(L, num_blocks, block_size, Hkv, hd)``
        per leaf, shared by every slot through a per-slot block table
        (see ``repro.serve.blocks``). Only pure-attention families page;
        recurrent state is O(1) in sequence length and keeps the
        per-slot fixed cache."""
        cfg = self.cfg
        kind = transformer.block_kind(cfg)
        if kind not in ("dense", "moe"):
            raise ValueError(f"paged KV unsupported for family {kind!r} "
                             "(recurrent/cross-attn leaves are not paged)")
        L, Hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        shape = (L, num_blocks, block_size, Hkv, hd)
        return {"k": jnp.zeros(shape, cfg.dtype),
                "v": jnp.zeros(shape, cfg.dtype)}

    # ---------------- shape stand-ins ----------------
    def input_specs(self, shape) -> dict:
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        i32, dt = jnp.int32, cfg.dtype
        if shape.kind == "train":
            batch = {"tokens": sds((B, S + 1), i32)}
            if cfg.frontend == "vision":
                batch["tokens"] = sds((B, S - cfg.n_patches + 1), i32)
                batch["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model), dt)
            if cfg.frontend == "audio":
                batch["frames"] = sds((B, cfg.n_frames, cfg.d_model), dt)
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = {"tokens": sds((B, S), i32)}
            if cfg.frontend == "vision":
                batch["tokens"] = sds((B, S - cfg.n_patches), i32)
                batch["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model), dt)
            if cfg.frontend == "audio":
                batch["frames"] = sds((B, cfg.n_frames, cfg.d_model), dt)
            return {"batch": batch}
        # decode: one token against a cache of capacity S
        cache = jax.eval_shape(lambda: self.init_cache(B, S))
        return {
            "token": sds((B, 1), i32),
            "cache": cache,
            "cache_len": sds((), i32),
        }


def build_model(cfg) -> Model:
    return Model(cfg)
