"""RWKV-6 "Finch" block [arXiv:2404.05892]: attention-free time-mix with
data-dependent decay (the paper's headline feature) + squared-ReLU channel-mix.

Recurrence per head (state S: hd x hd):
    out_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t   = diag(w_t) S_{t-1} + k_t^T v_t
with w_t = exp(-exp(w0 + lora(x_t)))  — data-dependent, per channel.

The sequence path is a ``lax.scan``; the Pallas kernel in
``repro.kernels.rwkv_scan`` implements the same recurrence with time-block
tiling for TPU. Decode carries (S, last_x) as the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

LORA_RANK = 64


def init_time_mix(rng, cfg, dtype=None):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    dtype = dtype or cfg.dtype
    ks = jax.random.split(rng, 10)
    r = min(LORA_RANK, d)
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),          # r,k,v,g,w token-shift mixes
        "w_r": layers.dense_init(ks[0], d, H * hd, dtype),
        "w_k": layers.dense_init(ks[1], d, H * hd, dtype),
        "w_v": layers.dense_init(ks[2], d, H * hd, dtype),
        "w_g": layers.dense_init(ks[3], d, H * hd, dtype),
        "w_o": layers.dense_init(ks[4], H * hd, d, dtype),
        "decay_lora_a": layers.dense_init(ks[5], d, r, dtype),
        "decay_lora_b": layers.dense_init(ks[6], r, H * hd, dtype),
        "decay_base": -5.0 * jnp.ones((H * hd,), jnp.float32),
        "bonus_u": jnp.zeros((H, hd), jnp.float32),
        "ln_out": jnp.ones((H * hd,), dtype),
    }


def init_channel_mix(rng, cfg, dtype=None):
    d = cfg.d_model
    dtype = dtype or cfg.dtype
    ks = jax.random.split(rng, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), dtype),
        "w_r": layers.dense_init(ks[0], d, d, dtype),
        "w_k": layers.dense_init(ks[1], d, cfg.d_ff, dtype),
        "w_v": layers.dense_init(ks[2], cfg.d_ff, d, dtype),
    }


def _shift(x, last_x):
    """x (B,S,d); last_x (B,d) value preceding x[:,0]. Returns x_{t-1}."""
    return jnp.concatenate([last_x[:, None, :], x[:, :-1, :]], axis=1)


def _decay(xw, p):
    """Data-dependent per-channel decay in (0,1). xw: (..., d)."""
    lora = jnp.tanh(xw @ p["decay_lora_a"]) @ p["decay_lora_b"]
    return jnp.exp(-jnp.exp(p["decay_base"] + lora.astype(jnp.float32)))


def _project(x, last_x, p, cfg):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    xx = _shift(x, last_x) - x
    mu = p["mu"]
    xr, xk, xv, xg, xw = (x + xx * mu[i] for i in range(5))
    r = (xr @ p["w_r"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"])
    w = _decay(xw, p).reshape(B, S, H, hd)
    return r, k, v, g, w


def wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence. r/k/v/w: (B,S,H,hd) f32; u: (H,hd);
    state: (B,H,hd,hd). Returns (out (B,S,H,hd), new_state).

    On TPU the Pallas kernel executes this (state carried in VMEM across
    time blocks — the HBM state round-trip of the XLA scan is rwkv's
    dominant roofline term); the lax.scan path is the CPU/oracle route."""
    if jax.default_backend() == "tpu" and r.shape[1] % 64 == 0:
        from repro.kernels.rwkv_scan.ops import wkv
        return wkv(r, k, v, w, u, state, bt=64)

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp                     # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]   # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S_ + u[..., :, None] * kv)
        S_ = w_t[..., :, None] * S_ + kv
        return S_, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def time_mix(x, p, cfg, cache=None):
    """cache: {"state": (B,H,hd,hd) f32, "last_x": (B,d)} or None."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    if cache is None:
        cache = {"state": jnp.zeros((B, H, hd, hd), jnp.float32),
                 "last_x": jnp.zeros((B, d), x.dtype)}
    r, k, v, g, w = _project(x, cache["last_x"], p, cfg)
    out, state = wkv_scan(r, k, v, w, p["bonus_u"], cache["state"])
    out = out.reshape(B, S, H * hd).astype(x.dtype)
    out = layers.rmsnorm(out, p["ln_out"], cfg.norm_eps)
    out = (out * g) @ p["w_o"]
    return out, {"state": state, "last_x": x[:, -1, :]}


def channel_mix(x, p, cfg, cache=None):
    B, S, d = x.shape
    if cache is None:
        cache = {"last_x": jnp.zeros((B, d), x.dtype)}
    xx = _shift(x, cache["last_x"]) - x
    xr = x + xx * p["mu"][0]
    xk = x + xx * p["mu"][1]
    r = jax.nn.sigmoid(xr @ p["w_r"])
    h = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return r * (h @ p["w_v"]), {"last_x": x[:, -1, :]}
