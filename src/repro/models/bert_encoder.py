"""Sentence encoder + sectioning classifier (paper §3.2.2).

The paper encodes each CV sentence with BERT (uncased_L-12_H-768_A-12 —
768-d [CLS] vectors) and classifies it into 4 sections with the Keras
model:

    dense_1: Dense(768 -> 200), dense_2: Dense(200 -> 4)
    Total params: 154,604  (153,800 + 804)

We reproduce the classifier EXACTLY (154,604 params, verified in tests)
and stand in for the frozen BERT with a small JAX transformer encoder
(mean-pooled) — the paper treats BERT as a black-box embedding service,
so its internals are not part of the contribution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.configs.base import ArchConfig

EMBED_DIM = 768
HIDDEN = 200
N_SECTIONS = 4


def encoder_config(vocab_size: int = 8192) -> ArchConfig:
    return ArchConfig(
        name="sentence-encoder", family="dense", n_layers=4, d_model=EMBED_DIM,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=vocab_size, act="gelu", rope="learned",
        dtype=jnp.float32, remat=False, source="arXiv:1810.04805 (stand-in)")


def init_encoder(rng, cfg: ArchConfig):
    ks = jax.random.split(rng, 4)
    blocks = []
    from repro.models import transformer
    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(cfg.dtype),
        "pos": (jax.random.normal(ks[1], (512, cfg.d_model)) * 0.02
                ).astype(cfg.dtype),
        "blocks": transformer.init_stack(ks[2], cfg, cfg.n_layers, "dense"),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }


def encode_sentences(params, cfg: ArchConfig, tokens: jnp.ndarray,
                     mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """tokens (B, S) int32 -> sentence embeddings (B, 768) (mean-pooled)."""
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :S, :]

    def body(h, bp):
        hh = layers.rmsnorm(h, bp["ln1"], cfg.norm_eps)
        o, _ = attention.attention_block(hh, bp["attn"], cfg, mode="train",
                                         causal=False)
        h = h + o
        hh = layers.rmsnorm(h, bp["ln2"], cfg.norm_eps)
        return h + layers.mlp(hh, bp["ffn"], cfg.act), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if mask is None:
        return jnp.mean(x, axis=1)
    m = mask[..., None].astype(x.dtype)
    return jnp.sum(x * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)


# ------------------------------------------------------- section classifier
def init_classifier(rng):
    """The paper's exact sequential model: 768->200->4 with biases."""
    k1, k2 = jax.random.split(rng)
    return {
        "dense_1": {"w": layers.dense_init(k1, EMBED_DIM, HIDDEN, jnp.float32),
                    "b": jnp.zeros((HIDDEN,), jnp.float32)},
        "dense_2": {"w": layers.dense_init(k2, HIDDEN, N_SECTIONS, jnp.float32),
                    "b": jnp.zeros((N_SECTIONS,), jnp.float32)},
    }


def classifier_n_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def classify_sections(params, embeddings: jnp.ndarray) -> jnp.ndarray:
    """embeddings (B, 768) -> section logits (B, 4)."""
    h = jnp.tanh(embeddings @ params["dense_1"]["w"] + params["dense_1"]["b"])
    return h @ params["dense_2"]["w"] + params["dense_2"]["b"]


def classifier_loss(params, embeddings, labels):
    logits = classify_sections(params, embeddings)
    return layers.softmax_xent(logits, labels)
