"""Shared building blocks: norms, activations, rotary embeddings, inits.

Params are plain pytrees (nested dicts of jnp arrays). Layer-stacked params
carry a leading L axis and are consumed by ``lax.scan`` in the backbones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init utils
def dense_init(rng, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def stacked(rng, n: int, init_fn):
    """vmap an init over a leading layer axis."""
    return jax.vmap(init_fn)(jax.random.split(rng, n))


# ---------------------------------------------------------------- norms
def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- activations
GATED_ACTS = ("swiglu", "geglu")


def act_fn(name: str):
    if name == "swiglu" or name == "silu":
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name in ("gelu", "geglu"):
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name}")


def mlp(x, p, act: str):
    """Gated (swiglu) or plain 2-matrix MLP. p: {w_in, w_out[, w_gate]}."""
    if "w_gate" in p:
        h = act_fn(act)(x @ p["w_gate"]) * (x @ p["w_in"])
    else:
        h = act_fn(act)(x @ p["w_in"])
    return h @ p["w_out"]


def init_mlp(rng, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(rng, 3)
    p = {
        "w_in": dense_init(ks[0], d_model, d_ff, dtype),
        "w_out": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if act in GATED_ACTS:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


# ---------------------------------------------------------------- rotary
def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections=(0.25, 0.375, 0.375)) -> jnp.ndarray:
    """Multimodal RoPE [arXiv:2409.12191]: rotary dims split into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, H, hd); positions: (B, 3, S) int32.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)                       # (half,)
    # section boundaries over the half-dims
    n_t = int(half * sections[0])
    n_h = int(half * sections[1])
    sec_id = jnp.concatenate([
        jnp.zeros((n_t,), jnp.int32),
        jnp.ones((n_h,), jnp.int32),
        jnp.full((half - n_t - n_h,), 2, jnp.int32),
    ])                                                   # (half,)
    # pos per (B, S, half): pick the section's position id
    pos_t = positions.astype(jnp.float32).transpose(0, 2, 1)   # (B, S, 3)
    pos = jnp.take(pos_t, sec_id, axis=-1)                      # (B, S, half)
    ang = pos[..., None, :] * freqs                      # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jnp.ndarray, d: int, dtype=jnp.float32):
    """Whisper-style sinusoidal position embedding, computed on the fly
    (no table => no max-length gate for the 32k/500k serving shapes).
    positions: (...,) int -> (..., d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean cross-entropy in f32. logits (..., V), labels (...) int.

    The gold logit is extracted with a fused one-hot dot rather than
    ``take_along_axis`` so a vocab-sharded logits tensor never gets
    all-gathered by the SPMD partitioner (the elementwise+reduce stays
    sharded; only the scalar partials are combined).
    """
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, V, dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
