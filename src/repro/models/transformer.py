"""Backbone blocks for every family + scan-over-layers stacks.

Each family defines: ``init_block(rng, cfg)``, and a block apply function
``(x, p, cfg, mode, cache, extras, plan) -> (x, new_cache, aux)``.
Blocks are stacked with a leading L axis and consumed by ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, rwkv6, ssm


# ----------------------------------------------------------------- init
def init_block(rng, cfg, *, kind: str):
    """kind: dense | moe | hybrid | rwkv | encoder | decoder_x (cross-attn)."""
    d = cfg.d_model
    dtype = cfg.dtype
    ks = jax.random.split(rng, 8)
    if kind == "rwkv":
        return {
            "ln1": jnp.ones((d,), dtype),
            "tmix": rwkv6.init_time_mix(ks[0], cfg),
            "ln2": jnp.ones((d,), dtype),
            "cmix": rwkv6.init_channel_mix(ks[1], cfg),
        }
    p = {
        "ln1": jnp.ones((d,), dtype),
        "attn": attention.init_attention(ks[0], cfg),
        "ln2": jnp.ones((d,), dtype),
    }
    if kind == "moe":
        p["moe"] = moe.init_moe(ks[1], cfg)
    else:
        p["ffn"] = layers.init_mlp(ks[1], d, cfg.d_ff, cfg.act, dtype)
    if kind == "hybrid":
        p["ssm"] = ssm.init_ssm(ks[2], cfg)
        p["ln_attn_out"] = jnp.ones((d,), dtype)
        p["ln_ssm_out"] = jnp.ones((d,), dtype)
    if kind == "decoder_x":
        p["lnx"] = jnp.ones((d,), dtype)
        p["xattn"] = attention.init_cross_attention(ks[3], cfg)
    return p


def block_kind(cfg) -> str:
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.family == "moe":
        return "moe"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.cross_attention:
        return "decoder_x"
    return "dense"


# ----------------------------------------------------------------- apply
def apply_block(x, p, cfg, *, kind, mode, cache=None, extras=None, plan=None):
    """Returns (x, new_cache, aux_loss). extras: dict with positions /
    mrope_positions / enc_kv / cache_len as applicable."""
    extras = extras or {}
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps

    if kind == "rwkv":
        tcache = None if cache is None else {"state": cache["state"],
                                             "last_x": cache["last_x_t"]}
        ccache = None if cache is None else {"last_x": cache["last_x_c"]}
        h, tnew = rwkv6.time_mix(layers.rmsnorm(x, p["ln1"], eps), p["tmix"],
                                 cfg, tcache)
        x = x + h
        h, cnew = rwkv6.channel_mix(layers.rmsnorm(x, p["ln2"], eps), p["cmix"],
                                    cfg, ccache)
        x = x + h
        new_cache = None
        if mode != "train":
            new_cache = {"state": tnew["state"], "last_x_t": tnew["last_x"],
                         "last_x_c": cnew["last_x"]}
        return x, new_cache, aux

    # --- attention families ---
    h = layers.rmsnorm(x, p["ln1"], eps)
    acache = None
    if cache is not None and "k" in cache:
        acache = {"k": cache["k"], "v": cache["v"]}
    attn_out, acache_new = attention.attention_block(
        h, p["attn"], cfg, mode=mode, cache=acache,
        cache_len=extras.get("cache_len"),
        positions=extras.get("positions"),
        mrope_positions=extras.get("mrope_positions"), plan=plan,
        block_table=extras.get("block_table"),
        paged_kernel=extras.get("paged_kernel", False),
        n_write=extras.get("n_write"))

    if kind == "hybrid":
        scache = None if cache is None else {"state": cache["ssm_state"]}
        ssm_out, snew = ssm.ssm_block(h, p["ssm"], cfg, scache)
        attn_out = layers.rmsnorm(attn_out, p["ln_attn_out"], eps)
        ssm_out = layers.rmsnorm(ssm_out, p["ln_ssm_out"], eps)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        x = x + attn_out
        snew = None

    if kind == "decoder_x":
        hx = layers.rmsnorm(x, p["lnx"], eps)
        x = x + attention.cross_attention_block(hx, extras["enc_kv"],
                                                p["xattn"], cfg)

    h = layers.rmsnorm(x, p["ln2"], eps)
    if kind == "moe":
        ffn_out, aux = moe.moe_ffn(h, p["moe"], cfg, plan)
    else:
        ffn_out = layers.mlp(h, p["ffn"], cfg.act)
    x = x + ffn_out

    new_cache = None
    if mode != "train" and (acache_new is not None or snew is not None):
        new_cache = {}
        if acache_new is not None:
            new_cache.update(acache_new)
        if snew is not None:
            new_cache["ssm_state"] = snew["state"]
    return x, new_cache, aux


# ----------------------------------------------------------------- stack
def init_stack(rng, cfg, n_layers: int, kind: str):
    return layers.stacked(rng, n_layers,
                          lambda k: init_block(k, cfg, kind=kind))


def apply_stack(x, blocks, cfg, *, kind, mode, cache=None, extras=None,
                plan=None):
    """Apply the stacked layer params.

    All modes scan over the L axis. (§Perf iteration log: unrolling the
    decode loop was tried and REFUTED — rebuilding the stacked cache with
    ``jnp.stack`` plus per-layer dtype converts kept more buffers live
    than the scan's in-place loop state: 33.7 vs 27.0 GiB peak on the
    deepseek-7b x decode_32k dry-run.)

    cache: pytree stacked over L (or None). Returns (x, new_cache, aux_sum).
    """
    def body(carry, xs):
        h = carry
        bp, c = xs
        if plan is not None and mode == "train":
            h = plan.constrain_residual(h)
        h, new_c, aux = apply_block(h, bp, cfg, kind=kind, mode=mode,
                                    cache=c, extras=extras, plan=plan)
        return h, (new_c, aux)

    fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
    xs = (blocks, cache)
    x, (new_cache, aux) = jax.lax.scan(fn, x, xs)
    return x, new_cache, jnp.sum(aux)
