"""GQA attention: full/sliding-window causal for train+prefill, and
single-token decode against a (possibly sequence-sharded) KV cache.

These are the pure-jnp paths used for CPU smoke tests and for the dry-run
lowering (the SPMD partitioner turns the softmax/contraction over a
sequence-sharded KV cache into the flash-decoding LSE-combine collectives).
On TPU the hot paths swap in the Pallas kernels from ``repro.kernels``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_attention(rng, cfg, dtype=None):
    d, hd = cfg.d_model, cfg.hd
    dtype = dtype or cfg.dtype
    ks = jax.random.split(rng, 4)
    p = {
        "w_q": layers.dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "w_kv": layers.dense_init(ks[1], d, 2 * cfg.n_kv_heads * hd, dtype),
        "w_o": layers.dense_init(ks[2], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def qkv(x, p, cfg, positions=None, mrope_positions=None):
    """Project to q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with rope + qk_norm."""
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ p["w_q"]).reshape(B, S, cfg.n_heads, hd)
    kv = (x @ p["w_kv"]).reshape(B, S, 2, cfg.n_kv_heads, hd)
    k, v = kv[:, :, 0], kv[:, :, 1]
    if cfg.qk_norm:
        q = layers.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope == "rope":
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        assert mrope_positions is not None
        q = layers.apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(kv, G: int):
    """(B,T,Hkv,hd) -> (B,T,Hq,hd) by repeating each kv head G times.

    The repeat-KV formulation (vs grouping q into (Hkv,G,hd)) keeps the
    q-head axis intact, so head-sharded attention never reshapes a
    sharded dim — the (Hq)->(Hkv,G) reshape forced an all-to-all rehard
    of q/scores per layer under TP (§Perf, minitron-8b x train_4k). The
    repeat is a broadcast: per device it materializes only local heads.
    """
    if G == 1:
        return kv
    B, T, Hkv, hd = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (B, T, Hkv, G, hd)) \
        .reshape(B, T, Hkv * G, hd)


def _gqa_scores(q, k):
    """q (B,S,Hq,hd), k (B,T,Hkv,hd) -> scores (B,Hq,S,T) in f32."""
    B, S, Hq, hd = q.shape
    kx = _expand_kv(k, Hq // k.shape[2])
    s = jnp.einsum("bshd,bthd->bhst", q, kx,
                   preferred_element_type=jnp.float32)
    return s / jnp.sqrt(jnp.asarray(hd, jnp.float32))


def _combine(scores, v, Hq: int):
    """scores (B,Hq,S,T) f32, v (B,T,Hkv,hd) -> out (B,S,Hq*hd)."""
    B, _, S, T = scores.shape
    vx = _expand_kv(v, Hq // v.shape[2])
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), vx)
    return o.reshape(B, S, Hq * v.shape[-1])


Q_CHUNK = 1024  # query-block size for the chunked jnp path


def _masked_attention(q, k, v, q_offset, *, sliding_window=0, causal=True):
    """q (B,S,Hq,hd) at absolute positions q_offset + [0,S)."""
    S, T = q.shape[1], k.shape[1]
    scores = _gqa_scores(q, k)
    i = jnp.arange(S)[:, None] + q_offset     # absolute q positions
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= j <= i
    if sliding_window:
        mask &= j > i - sliding_window
    scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    return _combine(scores, v, q.shape[2])


def causal_attention(q, k, v, *, sliding_window: int = 0, causal: bool = True):
    """Full or sliding-window (causal) attention; q/k/v aligned in time.

    Long sequences are processed in query chunks (``lax.scan``) so the
    score tensor never materializes at (S, T) — the XLA-level analogue of
    the Pallas flash kernel's q-block grid.
    """
    B, S, Hq, hd = q.shape
    T = k.shape[1]
    if S <= Q_CHUNK or S % Q_CHUNK:
        return _masked_attention(q, k, v, T - S, sliding_window=sliding_window,
                                 causal=causal)
    nc = S // Q_CHUNK
    qc = jnp.moveaxis(q.reshape(B, nc, Q_CHUNK, Hq, hd), 1, 0)

    def body(_, inp):
        i, qi = inp
        o = _masked_attention(qi, k, v, T - S + i * Q_CHUNK,
                              sliding_window=sliding_window, causal=causal)
        return None, o

    # flash-attention memory behaviour: recompute each chunk's scores in
    # the backward pass instead of stacking (nc, B, H, Q_CHUNK, T) f32
    # score tensors for it (whisper train: 30 GiB of saved scores, §Perf)
    _, out = jax.lax.scan(jax.checkpoint(body), None, (jnp.arange(nc), qc))
    # out: (nc, B, Q_CHUNK, Hq*hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, Hq * hd)


def decode_attention(q, k_cache, v_cache, n_valid, *, sliding_window: int = 0):
    """One new token per sequence attending to the cache.

    q: (B, 1, Hq, hd); k/v_cache: (B, T, Hkv, hd); n_valid: scalar or (B,)
    count of valid cache entries (the new token's K/V already written).

    With the cache sequence axis sharded, the softmax reductions and the
    PV contraction lower to partial-max/partial-sum + all-reduce — i.e.
    flash-decoding style LSE combination, inserted by the partitioner.
    """
    scores = _gqa_scores(q, k_cache)                       # (B,Hq,1,T)
    T = k_cache.shape[1]
    j = jnp.arange(T)
    n_valid = jnp.asarray(n_valid)
    valid = j[None, :] < n_valid.reshape(-1, 1)            # (B or 1, T)
    if sliding_window:
        valid &= j[None, :] >= n_valid.reshape(-1, 1) - sliding_window
    scores = jnp.where(valid[:, None, None, :], scores,
                       jnp.finfo(jnp.float32).min)
    return _combine(scores, v_cache, q.shape[2])


def verify_decode_attention(q, k_cache, v_cache, base, *, sliding_window=0):
    """Multi-token (speculative verify) decode against a stripe cache.

    q: (B, S, Hq, hd) — S = k+1 tokens per row at absolute positions
    ``base[b] + [0, S)`` (their K/V already written); k/v_cache:
    (B, T, Hkv, hd); base: (B,) tokens cached per row *before* this
    window. Causal masking inside the window: query j attends to cache
    positions <= base[b] + j, so position j's output conditions on the
    committed context plus proposals d_1..d_j — exactly what j+1
    sequential ``decode_attention`` calls would each see.
    """
    scores = _gqa_scores(q, k_cache)                       # (B,Hq,S,T)
    S, T = q.shape[1], k_cache.shape[1]
    base = jnp.asarray(base).reshape(-1, 1, 1)             # (B,1,1)
    i = base + jnp.arange(S)[None, :, None]                # abs q position
    j = jnp.arange(T)[None, None, :]
    valid = j <= i
    if sliding_window:
        valid &= j > i - sliding_window
    scores = jnp.where(valid[:, None, :, :], scores,
                       jnp.finfo(jnp.float32).min)
    return _combine(scores, v_cache, q.shape[2])


def paged_verify_attention(q, pool_k, pool_v, k_new, v_new, block_table,
                           cache_len, n_write, *, sliding_window: int = 0,
                           use_kernel: bool = False):
    """Multi-token window against the KV block pool: the speculative
    **verify** step and the **chunked-prefill** step share this path (a
    prompt chunk is a window of known tokens scattered against the
    partially-resident prompt; the causal-inside-the-window mask is
    exactly the partial-prompt causal mask).

    q/k_new/v_new: (B, S, H*, hd) — S window tokens per row at
    positions ``cache_len[b] + [0, S)``; n_write: (B,) tokens of the
    window row b actually owns blocks for (``n_spec + 1`` when
    verifying, the row's chunk token count when chunk-prefilling; 0 for
    parked riders). Window token j of row b scatters at
    ``(block_table[b, (len+j) // bs], (len+j) % bs)`` when ``j <
    n_write[b]`` and is **diverted to the scratch block** otherwise —
    a row must never scatter speculative K/V into a block it has not
    been granted (it could still be shared with another sequence, or
    not allocated at all). Reads past a row's n_write are garbage but
    masked out of every output the caller commits (acceptance is capped
    at n_spec). Returns (out (B, S, Hq*hd), new_pool_k, new_pool_v).

    ``use_kernel`` runs the **fused multi-token Pallas kernel**
    (``kernels.paged_attention.paged_window_attention``): ONE launch
    covers the whole (q_len, kv_len) window — every window query of
    every row rides the same grid step, masked causally *inside* the
    window (query j of row b sees cache positions <= cache_len[b] + j,
    its per-row base length) — with the pool still read in place
    through the scalar-prefetched block table. The jnp path gathers
    once and applies the same causal-in-window mask.
    """
    from repro.serve.blocks import SCRATCH_BLOCK
    bs = pool_k.shape[1]
    B, S = q.shape[:2]
    base = jnp.asarray(cache_len, jnp.int32).reshape(-1)    # (B,)
    pos = base[:, None] + jnp.arange(S)[None, :]            # (B,S)
    rows = jnp.arange(B)
    safe = jnp.arange(S)[None, :] < jnp.asarray(n_write,
                                                jnp.int32).reshape(-1, 1)
    phys = jnp.where(safe, block_table[rows[:, None], pos // bs],
                     SCRATCH_BLOCK)                         # (B,S)
    pool_k = pool_k.at[phys, pos % bs].set(k_new.astype(pool_k.dtype))
    pool_v = pool_v.at[phys, pos % bs].set(v_new.astype(pool_v.dtype))
    max_blocks = block_table.shape[1]
    if use_kernel:
        from repro.kernels.paged_attention.ops import (
            paged_window_attention as _window_kernel)
        out, _ = _window_kernel(q, pool_k, pool_v, block_table, base,
                                sliding_window=sliding_window)
        return out.reshape(B, S, -1), pool_k, pool_v
    gk = pool_k[block_table].reshape(B, max_blocks * bs, *pool_k.shape[2:])
    gv = pool_v[block_table].reshape(B, max_blocks * bs, *pool_v.shape[2:])
    out = verify_decode_attention(q, gk, gv, base,
                                  sliding_window=sliding_window)
    return out, pool_k, pool_v


def paged_decode_attention(q, pool_k, pool_v, k_new, v_new, block_table,
                           cache_len, *, sliding_window: int = 0,
                           use_kernel: bool = False):
    """Decode one token per sequence against a shared KV **block pool**.

    q/k_new/v_new: (B, 1, H*, hd); pool_k/pool_v: (num_blocks, bs, Hkv,
    hd); block_table: (B, max_blocks) int32; cache_len: (B,) tokens
    already cached per row. Row b's logical position j lives at
    ``(block_table[b, j // bs], j % bs)`` — the new token's K/V is
    scattered there first (owned blocks are disjoint across rows — with
    prefix sharing the engine copy-on-writes any shared tail before the
    step — so the scatter never collides; unowned table entries point at
    the reserved scratch block 0). Returns (out, new_pool_k, new_pool_v).

    Two read paths behind ``use_kernel``:

    * **False (portable jnp reference)** — gather each row's effective
      cache through its table row into a transient (B, max_blocks*bs)
      buffer and run the same masked ``decode_attention`` as the stripe
      path, so the attention math — and therefore the emitted token
      stream — is unchanged.
    * **True (Pallas kernel)** — ``kernels.paged_attention`` reads K/V
      through the block table *in place* (scalar-prefetched table drives
      the BlockSpec index maps); no transient gather. This is the
      q_len = 1 **degenerate case of the fused window kernel** that
      also serves speculative verify and chunked prefill (see
      ``paged_verify_attention``) — one kernel body behind every paged
      consumer. Compiled on TPU, interpret mode elsewhere; held
      bit-exact (f32) against its streaming jnp oracle by the
      differential grids in ``tests/test_kernels.py``.
    """
    bs = pool_k.shape[1]
    idx = jnp.asarray(cache_len, jnp.int32).reshape(-1)     # (B,)
    rows = jnp.arange(idx.shape[0])
    phys = block_table[rows, idx // bs]                     # (B,)
    pool_k = pool_k.at[phys, idx % bs].set(k_new[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[phys, idx % bs].set(v_new[:, 0].astype(pool_v.dtype))
    B, max_blocks = block_table.shape
    if use_kernel:
        from repro.kernels.paged_attention.ops import (
            paged_decode_attention as _paged_kernel)
        out, _ = _paged_kernel(q[:, 0], pool_k, pool_v, block_table, idx + 1,
                               sliding_window=sliding_window)
        return out.reshape(B, 1, -1), pool_k, pool_v
    gk = pool_k[block_table].reshape(B, max_blocks * bs, *pool_k.shape[2:])
    gv = pool_v[block_table].reshape(B, max_blocks * bs, *pool_v.shape[2:])
    out = decode_attention(q, gk, gv, idx + 1, sliding_window=sliding_window)
    return out, pool_k, pool_v


def attention_block(x, p, cfg, *, mode: str, cache=None, cache_len=None,
                    positions=None, mrope_positions=None, causal=True,
                    sliding_window=None, plan=None, block_table=None,
                    paged_kernel=False, n_write=None):
    """Full attention sub-block incl. output proj. Returns (out, new_cache).

    cache: dict(k=(B,T,Hkv,hd), v=(B,T,Hkv,hd)) or None — or, with
    ``block_table`` set, the paged pool dict(k=(num_blocks,bs,Hkv,hd), ...).
    In decode mode, ``x`` with more than one token per row is a
    **multi-token window** — a speculative verify window or a chunked
    prefill window: the S tokens write K/V at positions
    ``cache_len[b] + [0, S)`` (paged writes diverted to scratch past
    ``n_write[b]``) and attend causally inside the window against the
    already-resident cache.
    """
    win = cfg.sliding_window if sliding_window is None else sliding_window
    if mode == "decode" and x.shape[1] > 1:
        # ---- multi-token window (speculative verify / chunked prefill) ----
        B, S, _ = x.shape
        idx = jnp.asarray(cache_len, jnp.int32).reshape(-1)
        pos = idx[:, None] + jnp.arange(S)[None, :]          # (B,S)
        q, k, v = qkv(x, p, cfg, positions=pos,
                      mrope_positions=mrope_positions)
        if block_table is not None:
            nw = jnp.full((B,), S, jnp.int32) if n_write is None \
                else jnp.asarray(n_write, jnp.int32)
            o, k_cache, v_cache = paged_verify_attention(
                q, cache["k"], cache["v"], k, v, block_table, idx, nw,
                sliding_window=win, use_kernel=paged_kernel)
        else:
            rows = jnp.arange(B)[:, None]
            k_cache = cache["k"].at[rows, pos].set(
                k.astype(cache["k"].dtype))
            v_cache = cache["v"].at[rows, pos].set(
                v.astype(cache["v"].dtype))
            o = verify_decode_attention(q, k_cache, v_cache, idx,
                                        sliding_window=win)
        return o @ p["w_o"], {"k": k_cache, "v": v_cache}
    if mode == "decode":
        # cache_len = number of tokens already cached; the new token goes
        # at index cache_len and attends to indices [0, cache_len].
        # Scalar cache_len decodes all rows at one length (lock-step);
        # a (B,) vector gives every slot its own length (mixed-length
        # continuous batching — each row ropes, writes, and masks at its
        # own position).
        pos = cache_len if positions is None else positions
        q, k, v = qkv(x, p, cfg, positions=jnp.reshape(pos, (-1, 1)),
                      mrope_positions=mrope_positions)
        if plan is not None and plan.mesh is not None:
            # Flash-decoding layout (§Perf): the single-token q is tiny —
            # replicate its heads so the seq-sharded cache never reshards;
            # each model-group computes partial attention over its KV
            # slice and the softmax/PV reductions close with small psums.
            from jax.sharding import PartitionSpec as P
            b = plan._div(q.shape[0], plan.batch_axes)
            rep = lambda t: jax.lax.with_sharding_constraint(
                t, plan.ns(P(b, None, None, None)))
            q, k, v = rep(q), rep(k), rep(v)
        if block_table is not None:
            # paged KV: cache leaves are the shared block pool
            o, k_cache, v_cache = paged_decode_attention(
                q, cache["k"], cache["v"], k, v, block_table, cache_len,
                sliding_window=win, use_kernel=paged_kernel)
        else:
            idx = jnp.asarray(cache_len, jnp.int32)
            if idx.ndim == 0:
                k_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
                v_cache = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
            else:
                # per-slot write index: scatter row b's K/V at [b, idx[b]]
                rows = jnp.arange(k.shape[0])
                k_cache = cache["k"].at[rows, idx].set(
                    k[:, 0].astype(cache["k"].dtype))
                v_cache = cache["v"].at[rows, idx].set(
                    v[:, 0].astype(cache["v"].dtype))
            o = decode_attention(q, k_cache, v_cache, cache_len + 1,
                                 sliding_window=win)
        if plan is not None and plan.mesh is not None:
            # pin the joined attention output replicated as well — the
            # row-sharded w_o otherwise drags head-sharding back through
            # the combine and the partitioner re-shards the cache
            from jax.sharding import PartitionSpec as P
            o = jax.lax.with_sharding_constraint(
                o, plan.ns(P(plan._div(o.shape[0], plan.batch_axes),
                             None, None)))
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        q, k, v = qkv(x, p, cfg, positions=positions,
                      mrope_positions=mrope_positions)
        o = causal_attention(q, k, v, sliding_window=win, causal=causal)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    return o @ p["w_o"], new_cache


# ------------------------------------------------------------- cross-attn
def init_cross_attention(rng, cfg, dtype=None):
    d, hd = cfg.d_model, cfg.hd
    dtype = dtype or cfg.dtype
    ks = jax.random.split(rng, 3)
    return {
        "w_q": layers.dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "w_kv": layers.dense_init(ks[1], d, 2 * cfg.n_kv_heads * hd, dtype),
        "w_o": layers.dense_init(ks[2], cfg.n_heads * hd, d, dtype),
    }


def cross_attention_block(x, enc_kv, p, cfg):
    """x (B,S,d) attends to precomputed encoder K/V (B,T,Hkv,hd)."""
    B, S, _ = x.shape
    q = (x @ p["w_q"]).reshape(B, S, cfg.n_heads, cfg.hd)
    o = causal_attention(q, enc_kv["k"], enc_kv["v"], causal=False)
    return o @ p["w_o"]


def encode_cross_kv(enc_out, p, cfg):
    B, T, _ = enc_out.shape
    kv = (enc_out @ p["w_kv"]).reshape(B, T, 2, cfg.n_kv_heads, cfg.hd)
    return {"k": kv[:, :, 0], "v": kv[:, :, 1]}
