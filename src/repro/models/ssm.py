"""Mamba-style selective SSM path (used by the hymba hybrid heads).

Diagonal selective state space:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t
    y_t = C_t . h_t + D * u_t
with input-dependent dt, B, C (selectivity) and state size N = cfg.ssm_state.
Sequence path is ``lax.scan``; decode carries h (B, d_inner, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_ssm(rng, cfg, dtype=None):
    d, di, N = cfg.d_model, cfg.dinner, max(cfg.ssm_state, 1)
    dtype = dtype or cfg.dtype
    ks = jax.random.split(rng, 6)
    return {
        "w_in": layers.dense_init(ks[0], d, di, dtype),
        "w_gate": layers.dense_init(ks[1], d, di, dtype),
        "w_dt": layers.dense_init(ks[2], d, di, dtype),
        "w_bc": layers.dense_init(ks[3], d, 2 * N, dtype),
        "w_out": layers.dense_init(ks[4], di, d, dtype),
        "A_log": jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :]
                 * jnp.ones((di, 1), jnp.float32),       # (di, N)
        "D": jnp.ones((di,), jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32),
    }


def selective_scan(u, dt, B, C, A, D, state):
    """u,dt: (B,S,di) f32; B,C: (B,S,N) f32; A: (di,N); state: (B,di,N).

    Returns (y (B,S,di) f32, new_state).

    On TPU the Pallas kernel executes this (state carried in VMEM across
    time blocks); the lax.scan path is the CPU/oracle route.
    """
    if jax.default_backend() == "tpu" and u.shape[1] % 64 == 0 \
            and u.shape[2] % 32 == 0:
        from repro.kernels.ssm_scan.kernel import ssm_scan
        return ssm_scan(u, dt, B, C, A, D, state, bt=64, interpret=False)

    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp                         # (B,di),(B,di),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A)                 # (B,di,N)
        dBu = dt_t[..., None] * B_t[:, None, :] * u_t[..., None]
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, C_t) + D * u_t
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (u, dt, B, C))
    state, y = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(y, 0, 1), state


def ssm_block(x, p, cfg, cache=None):
    """x (B,S,d) -> (out (B,S,d), cache {"state": (B,di,N)})."""
    Bsz, S, d = x.shape
    di, N = cfg.dinner, max(cfg.ssm_state, 1)
    if cache is None:
        cache = {"state": jnp.zeros((Bsz, di, N), jnp.float32)}
    u = (x @ p["w_in"]).astype(jnp.float32)
    g = jax.nn.silu(x @ p["w_gate"])
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    bc = (x @ p["w_bc"]).astype(jnp.float32)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                    # (B,S,N) each
    A = -jnp.exp(p["A_log"])                              # (di,N), negative
    y, state = selective_scan(u, dt, Bm, Cm, A, p["D"], cache["state"])
    out = (y.astype(x.dtype) * g) @ p["w_out"]
    return out, {"state": state}
