"""Version-compat shims for the Pallas TPU API surface the kernels use.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
resolve whichever this jax ships once, here, so the four kernel modules
don't each carry (and drift) their own getattr dance.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
