"""Pure-jnp oracle for single-token GQA decode attention (+ LSE)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, n_valid, *, sliding_window: int = 0):
    """q: (B, Hq, hd); k/v: (B, Hkv, T, hd); n_valid: scalar int.

    Returns (out (B, Hq, hd) in q.dtype, lse (B, Hq) f32). LSE is the
    log-sum-exp of the masked scores — the quantity needed to merge
    partial attention across sequence shards (flash-decoding)."""
    B, Hq, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32)) \
        / jnp.sqrt(float(hd))
    j = jnp.arange(T)
    valid = j < n_valid
    if sliding_window:
        valid &= j >= n_valid - sliding_window
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    lse = jax.nn.logsumexp(s, axis=-1)                       # (B,Hkv,G)
    w = jnp.exp(s - lse[..., None])
    o = jnp.einsum("bkgt,bktd->bkgd", w, v.astype(jnp.float32))
    return (o.reshape(B, Hq, hd).astype(q.dtype),
            lse.reshape(B, Hq))


def merge_partials(outs, lses):
    """Merge per-shard (out, lse) partials: the LSE-combine used when the
    KV cache is sequence-sharded. outs: list of (B,Hq,hd); lses: (B,Hq)."""
    import numpy as np
    lse = jnp.stack(lses)                                    # (S_, B, Hq)
    m = jnp.max(lse, axis=0)
    w = jnp.exp(lse - m[None])                               # (S_, B, Hq)
    num = sum(w[i][..., None] * outs[i].astype(jnp.float32)
              for i in range(len(outs)))
    den = jnp.sum(w, axis=0)[..., None]
    return (num / den).astype(outs[0].dtype)
