"""Single-token GQA decode attention for TPU.

Grid (B, Hkv, T/bk): all G=Hq/Hkv query heads of one KV head are
processed together as a (G, hd) tile — on TPU this keeps the MXU busy on
what is otherwise a bandwidth-bound matvec (G rows amortize each KV tile
load, the GQA insight applied to the memory hierarchy). The KV-block
sweep is innermost with VMEM accumulators; ``n_valid`` arrives via scalar
prefetch (SMEM) so masking needs no HBM mask tensor.

Emits (out, lse): with a sequence-sharded cache each shard runs this
kernel over its local KV slice and partials merge with the closed-form
LSE combine (ref.merge_partials) via a tiny all-gather — flash-decoding
on TPU collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = float("-inf")


def _decode_kernel(n_valid_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, bk: int,
                   window: int, n_k_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n_valid = n_valid_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < n_valid
    if window:
        mask &= kpos >= n_valid - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _write():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_safe + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("sliding_window", "bk",
                                             "interpret"))
def decode_attention(q, k, v, n_valid, *, sliding_window: int = 0,
                     bk: int = 256, interpret: bool = True):
    """q (B,Hq,hd), k/v (B,Hkv,T,hd), n_valid scalar int32.
    Returns (out (B,Hq,hd), lse (B,Hq) f32)."""
    B, Hq, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bk = min(bk, T)
    assert T % bk == 0
    nk = T // bk
    qg = q.reshape(B, Hkv, G, hd)

    kernel = functools.partial(_decode_kernel, scale=1.0 / (hd ** 0.5),
                               bk=bk, window=sliding_window, n_k_blocks=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, *_: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, *_: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j, *_: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), qg, k, v)
    return out.reshape(B, Hq, hd), lse.reshape(B, Hq)
