"""Public op for decode attention (+ the sharded LSE-combine helper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention as _kernel
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                merge_partials)


def decode_attention(q, k, v, n_valid, *, sliding_window=0, bk=256,
                     force_ref=False):
    if force_ref:
        return decode_attention_ref(q, k, v, n_valid,
                                    sliding_window=sliding_window)
    on_tpu = jax.default_backend() == "tpu"
    return _kernel(q, k, v, n_valid, sliding_window=sliding_window, bk=bk,
                   interpret=not on_tpu)


def sharded_decode_attention(q, k_shards, v_shards, n_valid, **kw):
    """Flash-decoding over a sequence-sharded KV cache: run the kernel per
    shard (host loop stands in for the per-device program) and merge with
    the closed-form LSE combine."""
    outs, lses = [], []
    offset = 0
    for ks, vs in zip(k_shards, v_shards):
        t = ks.shape[2]
        local_valid = jnp.clip(n_valid - offset, 0, t)
        o, l = decode_attention(q, ks, vs, local_valid, **kw)
        outs.append(o)
        lses.append(l)
        offset += t
    return merge_partials(outs, lses)
