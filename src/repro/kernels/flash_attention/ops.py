"""Public op: jitted wrapper choosing the Pallas kernel (TPU) or the
interpret-mode kernel / jnp reference (CPU)."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal=True, sliding_window=0,
                    bq=128, bk=128, force_ref=False):
    """Layout: q (B, Hq, S, hd), k/v (B, Hkv, T, hd)."""
    if force_ref:
        return flash_attention_ref(q, k, v, causal=causal,
                                   sliding_window=sliding_window)
    on_tpu = jax.default_backend() == "tpu"
    return _kernel(q, k, v, causal=causal, sliding_window=sliding_window,
                   bq=bq, bk=bk, interpret=not on_tpu)


def attention_bshd(q, k, v, **kw):
    """Convenience for (B, S, H, hd) layouts used by the model zoo."""
    t = lambda x: x.transpose(0, 2, 1, 3)
    return t(flash_attention(t(q), t(k), t(v), **kw))
