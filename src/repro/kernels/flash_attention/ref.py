"""Pure-jnp oracle for the flash attention kernel (GQA, causal or
sliding-window)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sliding_window: int = 0) -> jnp.ndarray:
    """q: (B, Hq, S, hd); k/v: (B, Hkv, T, hd) -> (B, Hq, S, hd).
    Softmax in f32; output in q.dtype."""
    B, Hq, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, S, hd)
    s = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    i = jnp.arange(S)[:, None] + (T - S)
    j = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= j <= i
    if sliding_window:
        mask &= j > i - sliding_window
    s = jnp.where(mask, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return o.reshape(B, Hq, S, hd).astype(q.dtype)
