"""Blocked flash attention for TPU (GQA, causal / sliding-window).

TPU-native design (not a CUDA port): the grid is (B, Hq, S/bq, T/bk) with
the KV-block dimension innermost ("arbitrary" semantics) so the online-
softmax accumulators live in VMEM scratch across the KV sweep; q/k/v
tiles stream HBM->VMEM via BlockSpecs; the two matmuls per tile hit the
MXU with 128-aligned (bq, hd)x(hd, bk) shapes; masking is computed from
the grid indices with iota on the VPU (no mask tensor in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, bq: int, bk: int, causal: bool,
                  window: int, q_offset: int, n_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m == -inf)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _write():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q (B,Hq,S,hd), k/v (B,Hkv,T,hd) -> (B,Hq,S,hd)."""
    B, Hq, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    grid = (B, Hq, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (hd ** 0.5), bq=bq, bk=bk,
        causal=causal, window=sliding_window, q_offset=T - S, n_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
