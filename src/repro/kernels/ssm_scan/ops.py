"""Public op: jitted wrapper choosing the Pallas kernel (TPU; interpret
on CPU) or the jnp reference."""
from __future__ import annotations

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan as _kernel
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def selective_scan(u, dt, Bm, Cm, A, D, state, *, bt: int = 64,
                   force_ref: bool = False):
    """u/dt: (B,T,di); Bm/Cm: (B,T,N); A: (di,N); D: (di,);
    state: (B,di,N). Returns (y, final_state), both f32."""
    if force_ref:
        return ssm_scan_ref(u, dt, Bm, Cm, A, D, state)
    on_tpu = jax.default_backend() == "tpu"
    return _kernel(u, dt, Bm, Cm, A, D, state, bt=bt, interpret=not on_tpu)
