"""Pure-jnp oracle for the selective SSM scan (repro.models.ssm
restated standalone so the kernel test has no model dependency)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, dt, Bm, Cm, A, D, state):
    """u/dt: (B,T,di) f32; Bm/Cm: (B,T,N) f32; A: (di,N); D: (di,);
    state: (B,di,N) f32. Returns (y (B,T,di) f32, final_state)."""
    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None] * A)
        h = dA * h + (dt_t * u_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t) + D * u_t
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (u, dt, Bm, Cm))
    state, y = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(y, 0, 1), state
