"""Selective (Mamba-style) SSM scan for TPU — hymba's SSM path.

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t
    y_t = C_t . h_t + D * u_t

Grid (B, di/bd, T/bt): the (bd x N) diagonal state lives in VMEM scratch
and is carried across time blocks (innermost "arbitrary" grid dim);
u/dt stream in (bt, bd) tiles and the input-dependent B_t/C_t in (bt, N)
tiles shared by every channel block. Within a block the recurrence is a
``fori_loop`` of rank-1 state updates on the VPU (N = 16 for hymba, so a
state row fits one vreg lane group).

Why this matters (EXPERIMENTS §Roofline): the XLA lowering of the same
scan round-trips the (B, di, N) state through HBM every timestep —
hymba's train memory term is dominated by it. Here the state never
leaves VMEM within a (b, d)-block's pass over T; HBM traffic drops to
the streaming inputs/outputs, which is the kernel's lower bound.

TPU adaptation note: CUDA Mamba kernels hold h in registers per thread
(one channel each) and sync via shared memory; the TPU analogue is the
(bd, N) VMEM tile with VPU lane parallelism over channels — same
dataflow, memory-hierarchy-native.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, s0_ref,
                y_ref, sT_ref, state_ref, *, bt: int, n_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = s0_ref[0]

    A = a_ref[...].astype(jnp.float32)                   # (bd, N)
    D = d_ref[0].astype(jnp.float32)                     # (bd,)

    def step(t, _):
        u_t = u_ref[0, t].astype(jnp.float32)            # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)          # (bd,)
        B_t = b_ref[0, t].astype(jnp.float32)            # (N,)
        C_t = c_ref[0, t].astype(jnp.float32)            # (N,)
        h = state_ref[...]                               # (bd, N)
        dA = jnp.exp(dt_t[:, None] * A)
        h = dA * h + (dt_t * u_t)[:, None] * B_t[None, :]
        state_ref[...] = h
        y_ref[0, t] = (h @ C_t + D * u_t).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, bt, step, ())

    @pl.when(it == n_t_blocks - 1)
    def _write():
        sT_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "bd", "interpret"))
def ssm_scan(u, dt, Bm, Cm, A, D, state, *, bt: int = 64, bd: int = 0,
             interpret: bool = True):
    """u/dt: (B,T,di); Bm/Cm: (B,T,N); A: (di,N); D: (di,);
    state: (B,di,N) f32. Returns (y (B,T,di) f32, final_state)."""
    B, T, di = u.shape
    N = Bm.shape[-1]
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    if not bd:
        bd = next((c for c in (256, 128, 64, 32) if di % c == 0), di)
    assert di % bd == 0, (di, bd)
    nt, nd = T // bt, di // bd

    kernel = functools.partial(_ssm_kernel, bt=bt, n_t_blocks=nt)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),   # u
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),   # dt
            pl.BlockSpec((1, bt, N), lambda b, d, t: (b, t, 0)),    # B
            pl.BlockSpec((1, bt, N), lambda b, d, t: (b, t, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, d, t: (d, 0)),          # A
            pl.BlockSpec((1, bd), lambda b, d, t: (0, d)),          # D
            pl.BlockSpec((1, bd, N), lambda b, d, t: (b, d, 0)),    # s0
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bd), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, bd, N), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, Bm, Cm, A, D.reshape(1, di), state)
    return y, sT
