"""Public op for paged decode attention (block-table in-place reads).

On TPU the Pallas kernel runs compiled; everywhere else it runs in
interpret mode so the *same* kernel body is what CI exercises — the
differential grid in ``tests/test_kernels.py`` holds it bit-exact (f32)
against ``ref.paged_decode_attention_ref`` and tolerance-close to the
independent gather oracle.
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention as _kernel)
from repro.kernels.paged_attention.ref import (gathered_decode_ref,
                                               paged_decode_attention_ref)

__all__ = ["paged_decode_attention", "paged_decode_attention_ref",
           "gathered_decode_ref"]


def paged_decode_attention(q, pool_k, pool_v, block_table, lengths, *,
                           sliding_window: int = 0, force_ref: bool = False):
    """q (B,Hq,hd); pool_k/pool_v (num_blocks, bs, Hkv, hd); block_table
    (B, max_blocks) int32; lengths (B,) valid tokens per row (new token
    already scattered). Returns (out (B,Hq,hd), lse (B,Hq) f32)."""
    if force_ref:
        return paged_decode_attention_ref(q, pool_k, pool_v, block_table,
                                          lengths,
                                          sliding_window=sliding_window)
    on_tpu = jax.default_backend() == "tpu"
    return _kernel(q, pool_k, pool_v, block_table, lengths,
                   sliding_window=sliding_window, interpret=not on_tpu)
