"""Public ops for fused paged attention (block-table in-place reads).

One Pallas kernel body serves every paged consumer — plain decode
(``paged_decode_attention``, the q_len = 1 degenerate case),
speculative verify, and chunked prefill windows
(``paged_window_attention``, q_len > 1 with causal-in-window masking
and per-row base lengths). On TPU the kernel runs compiled; everywhere
else it runs in interpret mode so the *same* kernel body is what CI
exercises — the differential grids in ``tests/test_kernels.py`` hold it
bit-exact (f32) against the streaming oracles in ``ref.py`` and
tolerance-close to the independent gather oracles.
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import (
    paged_decode_attention as _decode_kernel,
    paged_window_attention as _window_kernel)
from repro.kernels.paged_attention.ref import (gathered_decode_ref,
                                               gathered_window_ref,
                                               paged_decode_attention_ref,
                                               paged_window_attention_ref)

__all__ = ["paged_decode_attention", "paged_decode_attention_ref",
           "paged_window_attention", "paged_window_attention_ref",
           "gathered_decode_ref", "gathered_window_ref"]


def paged_decode_attention(q, pool_k, pool_v, block_table, lengths, *,
                           sliding_window: int = 0, force_ref: bool = False):
    """q (B,Hq,hd); pool_k/pool_v (num_blocks, bs, Hkv, hd); block_table
    (B, max_blocks) int32; lengths (B,) valid tokens per row (new token
    already scattered). Returns (out (B,Hq,hd), lse (B,Hq) f32)."""
    if force_ref:
        return paged_decode_attention_ref(q, pool_k, pool_v, block_table,
                                          lengths,
                                          sliding_window=sliding_window)
    on_tpu = jax.default_backend() == "tpu"
    return _decode_kernel(q, pool_k, pool_v, block_table, lengths,
                          sliding_window=sliding_window, interpret=not on_tpu)


def paged_window_attention(q, pool_k, pool_v, block_table, base_lens, *,
                           sliding_window: int = 0, force_ref: bool = False):
    """Fused multi-token window: q (B,S,Hq,hd) at absolute positions
    ``base_lens[b] + [0, S)`` (K/V already scattered — diverted writes
    landed in scratch and are masked by causality for every position
    the caller commits); base_lens (B,) int32 tokens resident per row
    before the window. Returns (out (B,S,Hq,hd), lse (B,S,Hq) f32)."""
    if force_ref:
        return paged_window_attention_ref(q, pool_k, pool_v, block_table,
                                          base_lens,
                                          sliding_window=sliding_window)
    on_tpu = jax.default_backend() == "tpu"
    return _window_kernel(q, pool_k, pool_v, block_table, base_lens,
                          sliding_window=sliding_window, interpret=not on_tpu)
