"""Fused paged GQA attention for TPU: one kernel, q_len >= 1.

The serving engine's KV lives in a shared block pool
``(num_blocks, block_size, Hkv, hd)`` per layer, and each slot maps its
logical positions through a per-slot block table (``repro.serve.blocks``).
The portable jnp path (`attention.paged_decode_attention` /
`attention.paged_verify_attention`) *gathers* each row's blocks into a
transient ``(B, max_blocks*bs)`` buffer before the attention math —
O(B x max_seq) of extra HBM traffic per layer per step.

This kernel reads the pool **in place**: the block table and per-row
base lengths ride in as scalar-prefetch operands (SMEM), and the K/V
BlockSpec index maps dereference the table, so each grid step DMAs
exactly one physical block from the pool into VMEM. Nothing is
materialized per-row; the only per-step HBM traffic is the blocks a row
actually owns (plus masked-off scratch for table tails).

One fused tile serves every serving consumer:

* **plain decode** — ``q_len = 1``, the degenerate window;
* **speculative verify** — ``q_len = k+1`` draft windows, each query
  masked causally *inside* the window;
* **chunked prefill** — a prompt chunk is a window of known tokens
  against the partially-resident prompt.

Grid (B, Hkv, max_blocks): all ``q_len * G`` query rows of one KV head
(G = Hq/Hkv) are processed together as an ``(S*G, hd)`` tile (the same
MXU-occupancy trick as ``decode_attention``, extended across the
window), with the block sweep innermost over flash-style VMEM
accumulators. **Causal-in-window masking** happens per query row:
window position ``w = row // G`` of batch row ``b`` attends to cache
positions ``[0, base[b] + w]`` — ``base`` is the per-row count of
tokens resident *before* the window, so every window token conditions
on the committed context plus its own in-window prefix, exactly what
``w+1`` sequential single-token calls would each see. Rows at
different base lengths mask per-row via the prefetched vector — ragged
continuous batching needs no padding and no HBM mask tensor.

Emits (out, lse) so sequence-sharded pools can merge partials with the
same closed-form LSE combine as the stripe decode kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

NEG_INF = float("-inf")


def _rescale_accumulate(p, alpha, v, acc, *, deterministic: bool):
    """One flash-attention accumulate step as a SINGLE contraction.

    acc (R, hd+1) carries the output accumulator in [:, :hd] and the
    softmax denominator in [:, hd]. The classic update
    ``alpha * acc + [p @ v, sum(p)]`` leaves XLA free to seed the dot's
    reduction with the rescaled addend (FMA / accumulator-init fusion),
    which rounds differently per compilation context — the one freedom
    that broke bit-exactness between the compiled kernel and its jnp
    oracle. Folding the rescale into the matmul removes the seeding:

        [p | diag(alpha)] @ [[v | 1], [acc]]

    is ONE (R, bs+R) x (bs+R, hd+1) contraction — every product
    (including ``alpha_r * acc_r``) enters the same reduction, and the
    denominator column rides along for free.

    ``deterministic`` (the interpret/oracle mode) additionally pins the
    rounding order: the contraction is lowered as a broadcast multiply
    into an ``_exact_sum`` add chain instead of a ``dot_general`` (whose
    small-shape emitter reassociates per context). The compiled TPU
    path keeps the plain ``dot_general`` (MXU) — bit-parity across
    hardware is meaningless anyway.
    """
    R = p.shape[0]
    p_aug = jnp.concatenate(
        [p, jnp.where(jnp.eye(R, dtype=bool), alpha, 0.0)], axis=1)
    v_aug = jnp.concatenate(
        [jnp.concatenate([v, jnp.ones((v.shape[0], 1), jnp.float32)],
                         axis=1), acc], axis=0)
    if not deterministic:
        return jax.lax.dot_general(p_aug, v_aug, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    return _exact_sum(p_aug[:, :, None] * v_aug[None, :, :], 1)


def _exact_sum(x, axis: int):
    """Sum with ONE defined rounding order: a sequential ``lax.scan``
    chain of plain adds. An XLA ``reduce`` leaves the backend free to
    split the reduction loop into partial accumulators (reassociation)
    or lower a minor-axis reduce as a horizontal SIMD tree — both
    context-dependent orders that show up as kernel-vs-oracle ulp
    drift. IEEE adds are exactly rounded, so a fixed-order add chain
    yields the same bits under any codegen of the adds themselves."""
    xs = jnp.moveaxis(x, axis, 0)
    total, _ = jax.lax.scan(lambda c, t: (c + t, None),
                            jnp.zeros_like(xs[0]), xs)
    return total


def _p_and_alpha(s, mask, m_prev, m_safe):
    """Softmax weights p = exp(s - m_safe) and rescale alpha =
    exp(m_prev - m_safe) out of ONE (R, bs+1) exp op. Besides saving a
    transcendental launch, this narrows a determinism gap: a lone
    (R, 1)-shaped exp was observed to compile differently depending on
    unrelated ops elsewhere in the module (vector-vs-scalar codegen of
    the polynomial), while the wide exp is far more stable — one shared
    op means p and alpha can't round apart from each other."""
    z = jnp.concatenate([s, m_prev], axis=1) - m_safe        # (R, bs+1)
    e = jnp.exp(z)
    p = jnp.where(mask, e[:, :-1], 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), e[:, -1:], 0.0)
    return p, alpha


def _qk_scores(q, k, scale: float, *, deterministic: bool):
    """Masked-score contraction q (R, hd) x k (bs, hd) -> (R, bs).
    Same determinism split as ``_rescale_accumulate``: ``dot_general``
    for the compiled TPU path; a broadcast multiply feeding an
    ``_exact_sum`` add chain for the interpret/oracle mode."""
    if not deterministic:
        return jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32) * scale
    return _exact_sum(q[:, None, :] * k[None, :, :], 2) * scale


def _window_mask(s_shape, j: int, base, *, bs: int, G: int, window: int):
    """Causal-in-window validity for the (R, bs) score tile of KV block
    ``j``: query row r is window position ``w = r // G`` of its batch
    row, valid through cache position ``base + w`` (its own scatter
    included), so ``n_valid = base + w + 1`` — per query row, not per
    batch row. A sliding window then clips the low side at
    ``n_valid - window``. Integer-only, exact under any codegen."""
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s_shape, 1)
    w_off = jax.lax.broadcasted_iota(jnp.int32, s_shape, 0) // G
    n_valid = base + w_off + 1
    mask = kpos < n_valid
    if window:
        mask &= kpos >= n_valid - window
    return mask


def _paged_window_kernel(table_ref, base_ref, q_ref, k_ref, v_ref, o_ref,
                         lse_ref, acc_ref, m_ref, *, scale: float,
                         bs: int, G: int, window: int, n_blocks: int,
                         deterministic: bool):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    base = base_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                  # (S*G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = _qk_scores(q, k, scale, deterministic=deterministic)
    mask = _window_mask(s.shape, j, base, bs=bs, G=G, window=window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p, alpha = _p_and_alpha(s, mask, m_prev, m_safe)
    acc_ref[...] = _rescale_accumulate(p, alpha, v, acc_ref[...],
                                       deterministic=deterministic)
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _write():
        l = jnp.maximum(acc_ref[:, -1:], 1e-30)
        o_ref[0, 0] = (acc_ref[:, :-1] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_safe + jnp.log(l))[:, 0]


@functools.partial(jax.jit, static_argnames=("sliding_window", "interpret"))
def paged_window_attention(q, pool_k, pool_v, block_table, base_lens, *,
                           sliding_window: int = 0, interpret: bool = True):
    """The fused multi-token tile. q (B, S, Hq, hd) — S window tokens
    per row at absolute positions ``base_lens[b] + [0, S)``, their K/V
    already scattered into the pool; pool_k/pool_v (num_blocks, bs,
    Hkv, hd); block_table (B, max_blocks) int32; base_lens (B,) int32
    tokens resident per row *before* the window. Window query w of row
    b attends to cache positions ``[0, base_lens[b] + w]`` (causal in
    the window). Returns (out (B,S,Hq,hd) in q.dtype, lse (B,S,Hq) f32).

    ``S = 1`` with ``base_lens = lengths - 1`` is exactly the classic
    single-token paged decode — one code path, every consumer."""
    B, S, Hq, hd = q.shape
    bs, Hkv = pool_k.shape[1], pool_k.shape[2]
    G = Hq // Hkv
    R = S * G
    max_blocks = block_table.shape[1]
    # (B,S,Hkv,G,hd) -> (B,Hkv,S,G,hd) -> (B,Hkv,S*G,hd): all of one KV
    # head's window queries ride one MXU tile; row r is window position
    # r // G, query head r % G.
    qg = jnp.transpose(q.reshape(B, S, Hkv, G, hd),
                       (0, 2, 1, 3, 4)).reshape(B, Hkv, R, hd)

    kernel = functools.partial(_paged_window_kernel,
                               scale=1.0 / (hd ** 0.5), bs=bs, G=G,
                               window=sliding_window, n_blocks=max_blocks,
                               deterministic=interpret)

    # The index maps receive the scalar-prefetch refs after the grid
    # indices: K/V tiles are addressed *through the block table*, so the
    # pool is read in place — physical block table[b, j] is the (b, ., j)
    # step's tile, whatever pool slot it landed in at admission time.
    flat_table = block_table.reshape(-1).astype(jnp.int32)

    def kv_map(b, h, j, table, lens):
        return (table[b * max_blocks + j], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, max_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, R), lambda b, h, j, *_: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((R, hd + 1), jnp.float32),    # acc | denominator
            pltpu.VMEM((R, 1), jnp.float32),         # running max
        ],
    )
    out, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, R, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, R), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(flat_table, jnp.asarray(base_lens, jnp.int32).reshape(-1), qg,
      pool_k, pool_v)
    out = jnp.transpose(out.reshape(B, Hkv, S, G, hd),
                        (0, 2, 1, 3, 4)).reshape(B, S, Hq, hd)
    lse = jnp.transpose(lse.reshape(B, Hkv, S, G),
                        (0, 2, 1, 3)).reshape(B, S, Hq)
    return out, lse


@functools.partial(jax.jit, static_argnames=("sliding_window", "interpret"))
def paged_decode_attention(q, pool_k, pool_v, block_table, lengths, *,
                           sliding_window: int = 0, interpret: bool = True):
    """Single-token decode — the fused window kernel at its S = 1
    degenerate case. q (B,Hq,hd); pool_k/pool_v (num_blocks, bs, Hkv,
    hd); block_table (B, max_blocks) int32; lengths (B,) int32 valid
    tokens per row (the new token's K/V already scattered into its
    block). Returns (out (B,Hq,hd) in q.dtype, lse (B,Hq) f32)."""
    base = jnp.asarray(lengths, jnp.int32).reshape(-1) - 1
    out, lse = paged_window_attention(q[:, None], pool_k, pool_v,
                                      block_table, base,
                                      sliding_window=sliding_window,
                                      interpret=interpret)
    return out[:, 0], lse[:, 0]
