"""jnp oracles for fused paged GQA attention (q_len >= 1 windows).

Two references at different distances from the kernel:

* ``paged_window_attention_ref`` replays the kernel's *exact* streaming
  recurrence — the same shared helpers per block (exact-sum score
  contraction, fused-exp weights, single-contraction rescale, the
  integer causal-in-window mask), in the same order, at the same
  ``(S*G, ...)`` tile shapes — as a ``lax.scan`` over the block sweep.
  In float32 the interpret-mode kernel's **attention output matches it
  bit-for-bit** (every sum and contraction on that path is an
  exactly-rounded, fixed-order add chain — see ``kernel._exact_sum`` /
  ``kernel._rescale_accumulate``); the auxiliary LSE output carries a
  few ULP of residue from ``log``'s per-context codegen. (True
  universal bitwise equality between two separately-compiled XLA:CPU
  programs is not contractable — the backend deletes
  ``optimization_barrier`` during compilation and keeps per-context
  freedom in transcendental codegen — so the differential grid asserts
  out <= 4 ulp / lse <= 32 ulp; a real kernel bug — wrong block, wrong
  mask, wrong rescale — is 3+ orders of magnitude larger.)
* ``gathered_window_ref`` is the independent oracle: gather the pool
  through the table (exactly what the portable jnp serving path does)
  and run one-shot causal-in-window masked softmax attention. The
  kernel and the streaming ref must agree with it to dtype-tiered
  tolerance — this catches a bug that the replayed recurrence would
  faithfully replay.

``paged_decode_attention_ref`` / ``gathered_decode_ref`` are the
single-token (S = 1) entry points the decode grid asserts against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (_p_and_alpha, _qk_scores,
                                                  _rescale_accumulate,
                                                  _window_mask)

NEG_INF = float("-inf")


def paged_window_attention_ref(q, pool_k, pool_v, block_table, base_lens, *,
                               sliding_window: int = 0):
    """Streaming-softmax oracle over the block sweep, q_len >= 1.

    q (B,S,Hq,hd) — S window tokens per row at positions
    ``base_lens[b] + [0, S)`` (K/V already scattered); pool_k/pool_v
    (num_blocks, bs, Hkv, hd); block_table (B, max_blocks) int32;
    base_lens (B,) tokens resident per row before the window. Returns
    (out (B,S,Hq,hd) in q.dtype, lse (B,S,Hq) f32)."""
    B, S, Hq, hd = q.shape
    bs, Hkv = pool_k.shape[1], pool_k.shape[2]
    G = Hq // Hkv
    R = S * G
    max_blocks = block_table.shape[1]
    qg = jnp.transpose(q.reshape(B, S, Hkv, G, hd),
                       (0, 2, 1, 3, 4)).reshape(B, Hkv, R, hd)
    scale = 1.0 / (hd ** 0.5)
    base_lens = jnp.asarray(base_lens, jnp.int32).reshape(-1)

    def one_head(qbh, table_b, base, h):
        qf = qbh.astype(jnp.float32)                        # (R, hd)

        def body(carry, j):
            acc, m_prev = carry
            phys = table_b[j]
            k = pool_k[phys, :, h].astype(jnp.float32)      # (bs, hd)
            v = pool_v[phys, :, h].astype(jnp.float32)
            s = _qk_scores(qf, k, scale, deterministic=True)
            mask = _window_mask(s.shape, j, base, bs=bs, G=G,
                                window=sliding_window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p, alpha = _p_and_alpha(s, mask, m_prev, m_safe)
            acc = _rescale_accumulate(p, alpha, v, acc, deterministic=True)
            return (acc, m_new), None

        # acc[:, :hd] is the output accumulator, acc[:, hd] the softmax
        # denominator — one fused contraction per block, same as the
        # kernel (see kernel._rescale_accumulate for why)
        init = (jnp.zeros((R, hd + 1), jnp.float32),
                jnp.full((R, 1), NEG_INF, jnp.float32))
        (acc, m), _ = jax.lax.scan(body, init, jnp.arange(max_blocks))
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        l = jnp.maximum(acc[:, -1:], 1e-30)
        return ((acc[:, :-1] / l).astype(q.dtype),
                (m_safe + jnp.log(l))[:, 0])

    # Deliberately a host loop, not a vmap: batching the (R, hd) x (bs, hd)
    # dots changes their reduction pattern on CPU and the kernel is held
    # to *bit*-exactness against this oracle — every dot here must run at
    # exactly the tile shape the interpret-mode grid step runs it at.
    # B and Hkv are single digits in every decode-step context.
    outs, lses = [], []
    for b in range(B):
        o_h, l_h = [], []
        for h in range(Hkv):
            o, l = one_head(qg[b, h], block_table[b], base_lens[b], h)
            o_h.append(o)
            l_h.append(l)
        outs.append(jnp.stack(o_h))
        lses.append(jnp.stack(l_h))
    out, lse = jnp.stack(outs), jnp.stack(lses)          # (B,Hkv,R,*)
    out = jnp.transpose(out.reshape(B, Hkv, S, G, hd),
                        (0, 2, 1, 3, 4)).reshape(B, S, Hq, hd)
    lse = jnp.transpose(lse.reshape(B, Hkv, S, G),
                        (0, 2, 1, 3)).reshape(B, S, Hq)
    return out, lse


def paged_decode_attention_ref(q, pool_k, pool_v, block_table, lengths, *,
                               sliding_window: int = 0):
    """Single-token streaming oracle — the window ref at S = 1.

    q (B,Hq,hd); lengths (B,) valid tokens per row. Returns
    (out (B,Hq,hd) in q.dtype, lse (B,Hq) f32)."""
    base = jnp.asarray(lengths, jnp.int32).reshape(-1) - 1
    out, lse = paged_window_attention_ref(q[:, None], pool_k, pool_v,
                                          block_table, base,
                                          sliding_window=sliding_window)
    return out[:, 0], lse[:, 0]


def gathered_window_ref(q, pool_k, pool_v, block_table, base_lens, *,
                        sliding_window: int = 0):
    """Independent window oracle: table gather + one-shot masked softmax
    with the causal-in-window mask (query w of row b sees cache
    positions <= base_lens[b] + w)."""
    B, S, Hq, hd = q.shape
    bs, Hkv = pool_k.shape[1], pool_k.shape[2]
    G = Hq // Hkv
    max_blocks = block_table.shape[1]
    T = max_blocks * bs
    gk = pool_k[block_table].reshape(B, T, Hkv, hd)
    gv = pool_v[block_table].reshape(B, T, Hkv, hd)
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    kx = jnp.moveaxis(gk, 2, 1).astype(jnp.float32)          # (B,Hkv,T,hd)
    vx = jnp.moveaxis(gv, 2, 1).astype(jnp.float32)
    s = jnp.einsum("bskgd,bktd->bkstg", qg, kx) / jnp.sqrt(float(hd))
    base = jnp.asarray(base_lens, jnp.int32).reshape(-1)
    i = base[:, None] + jnp.arange(S)[None, :]               # (B,S) abs pos
    j = jnp.arange(T)
    valid = j[None, None, :] <= i[:, :, None]                # (B,S,T)
    if sliding_window:
        valid &= j[None, None, :] > i[:, :, None] - sliding_window
    s = jnp.where(valid[:, None, :, :, None], s, -jnp.inf)   # (B,Hkv,S,T,G)
    lse = jax.nn.logsumexp(s, axis=3)                        # (B,Hkv,S,G)
    w = jnp.exp(s - lse[:, :, :, None, :])
    o = jnp.einsum("bkstg,bktd->bskgd", w, vx)
    out = o.reshape(B, S, Hq, hd).astype(q.dtype)
    return out, jnp.transpose(lse, (0, 2, 1, 3)).reshape(B, S, Hq)


def gathered_decode_ref(q, pool_k, pool_v, block_table, lengths, *,
                        sliding_window: int = 0):
    """Independent oracle: table gather + one-shot masked softmax."""
    B, Hq, hd = q.shape
    bs, Hkv = pool_k.shape[1], pool_k.shape[2]
    G = Hq // Hkv
    max_blocks = block_table.shape[1]
    T = max_blocks * bs
    gk = pool_k[block_table].reshape(B, T, Hkv, hd)
    gv = pool_v[block_table].reshape(B, T, Hkv, hd)
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    kx = jnp.moveaxis(gk, 2, 1).astype(jnp.float32)          # (B,Hkv,T,hd)
    vx = jnp.moveaxis(gv, 2, 1).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, kx) / jnp.sqrt(float(hd))
    lengths = jnp.asarray(lengths, jnp.int32).reshape(-1)
    j = jnp.arange(T)
    valid = j[None, :] < lengths[:, None]                    # (B, T)
    if sliding_window:
        valid &= j[None, :] >= lengths[:, None] - sliding_window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    lse = jax.nn.logsumexp(s, axis=-1)                       # (B,Hkv,G)
    w = jnp.exp(s - lse[..., None])
    o = jnp.einsum("bkgt,bktd->bkgd", w, vx)
    return (o.reshape(B, Hq, hd).astype(q.dtype), lse.reshape(B, Hq))
