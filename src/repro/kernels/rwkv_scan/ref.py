"""Pure-jnp oracle for the WKV6 recurrence (repro.models.rwkv6.wkv_scan
restated standalone so the kernel test has no model dependency)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, state):
    """r/k/v/w: (B, T, H, hd) f32 (w = per-step decay in (0,1));
    u: (H, hd); state: (B, H, hd, hd).
    Returns (out (B,T,H,hd) f32, final_state)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state
