"""WKV6 recurrence for TPU (data-dependent decay, Finch §4).

Grid (B, H, T/bt): the (hd x hd) per-head state lives in VMEM scratch and
is carried across time blocks (the grid's innermost "arbitrary" dim);
r/k/v/w stream in (bt, hd) tiles. Within a block the recurrence is a
``fori_loop`` of rank-1 updates on the VPU — hd=64 rows keep the update
vectorizable. (A chunked matmul formulation that moves intra-block work
onto the MXU is the documented follow-up in EXPERIMENTS §Perf; the
sequential-in-block form is the correctness baseline and is already
HBM-optimal: each element is read once.)

TPU adaptation note: the CUDA kernels for RWKV parallelize over (B, H,
hd-lanes) threads with the state in registers; the TPU analogue is the
(B, H) grid with state in VMEM and lane-parallelism via the VPU's 8x128
vregs — same dataflow, memory-hierarchy-native.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                state_ref, *, bt: int, n_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0]

    u = u_ref[0].astype(jnp.float32)                    # (hd,)

    def step(t, _):
        r_t = r_ref[0, t, 0].astype(jnp.float32)        # (hd,)
        k_t = k_ref[0, t, 0].astype(jnp.float32)
        v_t = v_ref[0, t, 0].astype(jnp.float32)
        w_t = w_ref[0, t, 0].astype(jnp.float32)
        S = state_ref[...]                              # (hd, hd)
        kv = k_t[:, None] * v_t[None, :]
        out = jnp.sum(r_t[:, None] * (S + u[:, None] * kv), axis=0)
        o_ref[0, t, 0] = out.astype(o_ref.dtype)
        state_ref[...] = w_t[:, None] * S + kv
        return ()

    jax.lax.fori_loop(0, bt, step, ())

    @pl.when(it == n_t_blocks - 1)
    def _write():
        sT_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def wkv_scan(r, k, v, w, u, state, *, bt: int = 64, interpret: bool = True):
    """r/k/v/w: (B,T,H,hd); u: (H,hd); state: (B,H,hd,hd) f32.
    Returns (out (B,T,H,hd) f32, final_state (B,H,hd,hd) f32)."""
    B, T, H, hd = r.shape
    bt = min(bt, T)
    assert T % bt == 0
    nt = T // bt

    kernel = functools.partial(_wkv_kernel, bt=bt, n_t_blocks=nt)
    ts = pl.BlockSpec((1, bt, 1, hd), lambda b, h, t: (b, t, h, 0))
    out, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[
            ts, ts, ts, ts,
            pl.BlockSpec((1, hd), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, state)
    return out, sT
