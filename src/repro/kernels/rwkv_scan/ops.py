"""Public op for the WKV6 scan."""
from __future__ import annotations

import jax

from repro.kernels.rwkv_scan.kernel import wkv_scan as _kernel
from repro.kernels.rwkv_scan.ref import wkv_ref


def wkv(r, k, v, w, u, state, *, bt=64, force_ref=False):
    if force_ref:
        return wkv_ref(r, k, v, w, u, state)
    on_tpu = jax.default_backend() == "tpu"
    return _kernel(r, k, v, w, u, state, bt=bt, interpret=not on_tpu)
