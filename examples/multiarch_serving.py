"""The paper's architecture x the assignment's model zoo: several
*different architectures* deployed as parallel Prediction-as-a-Service
endpoints on one shared device pool.

    PYTHONPATH=src python examples/multiarch_serving.py \
        [--archs qwen3-4b,rwkv6-1.6b,hymba-1.5b] [--requests 6]

Each architecture (reduced config) becomes one PaaS: a ServingEngine +
Scheduler behind a Service with replicas, started in supervisor priority
order, space-sharing the mesh via MultiModelServer semantics (on 1 CPU
device this degenerates to time-sharing; the dispatch/join structure is
identical). A router fans each request out to the services in parallel
(the paper's Fig 5 with NER sections replaced by LM architectures), and
the joined result reports per-service latency + generated tokens.
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics
import time

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.core.parallel import ParallelDispatcher
from repro.core.services import Replica, Service
from repro.core.supervisor import Supervisor
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import Scheduler


class LMPaaS:
    """One architecture as a Prediction-as-a-Service endpoint."""

    def __init__(self, arch: str, seed: int, *, batch=2, max_seq=64):
        self.arch = arch
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype=jax.numpy.float32)
        self.cfg = cfg
        model = build_model(cfg)
        params = model.init(jax.random.key(seed))
        self.scheduler = Scheduler(ServingEngine(
            model, params, batch_size=batch, max_seq=max_seq))
        self._rid = 0

    def __call__(self, payload):
        prompt, max_new = payload
        self._rid += 1
        req = Request(rid=self._rid, prompt=list(prompt),
                      max_new_tokens=max_new)
        assert self.scheduler.submit(req)
        done = self.scheduler.drain()
        (r,) = [d for d in done if d.rid == req.rid]
        return {"arch": self.arch, "tokens": r.out_tokens,
                "latency_s": r.latency_s}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="qwen3-4b,rwkv6-1.6b,hymba-1.5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=4)
    args = ap.parse_args()
    archs = [a.strip() for a in args.archs.split(",")]
    assert all(a in ARCH_IDS for a in archs), archs

    # priority-ordered deployment: services first, front-end router last
    sup = Supervisor()
    services = {}
    for i, arch in enumerate(archs):
        print(f"loading {arch} ...", flush=True)
        paas = LMPaaS(arch, seed=i)
        svc = Service(arch, replicas=[Replica(f"{arch}/0", paas)],
                      priority=2)
        services[arch] = sup.add(svc)
    dispatcher = ParallelDispatcher(mode="thread", max_workers=len(archs))

    def parse(payload):
        calls = [(a, services[a], payload) for a in archs]
        return dispatcher(calls)

    sup.add(Service("router", replicas=[Replica("router/0", parse)],
                    priority=3, depends_on=tuple(archs)))
    order = sup.start_all()
    print("startup order:", " -> ".join(order))

    router = sup.services["router"]
    rng = jax.random.key(99)
    lat = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (8,), 2, 500).tolist()
        t0 = time.perf_counter()
        res = router((prompt, args.max_new))
        lat.append(time.perf_counter() - t0)
        if i == 0:
            for a in archs:
                out = res.outputs[a]
                print(f"  {a:14s} ({get_config(a).family:6s}) "
                      f"-> {out['tokens']} "
                      f"({res.per_call_s[a]*1e3:.0f} ms)")
            print(f"  parallel={res.total_s*1e3:.0f} ms vs sequential-"
                  f"equivalent={res.sequential_equivalent_s*1e3:.0f} ms")
    print(f"\n{args.requests} requests x {len(archs)} architectures; "
          f"median join latency {statistics.median(lat)*1e3:.0f} ms")
    print("OK")


if __name__ == "__main__":
    main()
