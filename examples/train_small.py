"""Train a small LM end-to-end on the synthetic CV corpus (deliverable b).

    PYTHONPATH=src python examples/train_small.py \
        [--arch qwen3-4b] [--steps 150] [--d-model 256] [--layers 4]

Uses the full substrate: config -> model factory -> packed data pipeline
-> AdamW + cosine + clipping -> jitted train step -> chunked (GridFS-
style) checkpointing -> resume. The model is the assigned architecture's
family at reduced width (CPU container; the full-size configs are
exercised by the dry-run). Loss must drop >20% or the script exits 1.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import build_model
from repro.train import checkpoint, optimizer as opt_mod
from repro.train.data import DataConfig, PackedLMDataset
from repro.train.train_loop import TrainerConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, n_layers=args.layers,
                              d_model=args.d_model,
                              vocab_size=4096, dtype=jax.numpy.float32)
    model = build_model(cfg)
    n = sum(x.size for x in jax.tree.leaves(model.init(jax.random.key(0))))
    print(f"arch={args.arch} ({cfg.family}) {n/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    data = PackedLMDataset(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      batch_size=args.batch,
                                      n_documents=2048))
    print(f"packed corpus: {data.n_tokens():,} tokens")

    with tempfile.TemporaryDirectory() as ckroot:
        tc = TrainerConfig(
            n_steps=args.steps, log_every=max(args.steps // 10, 1),
            ckpt_every=args.steps // 2, ckpt_root=ckroot,
            opt=opt_mod.AdamWConfig(lr=1e-3, warmup_steps=20,
                                    total_steps=args.steps,
                                    weight_decay=0.01))
        res = train(model, data, tc)
        first, last = res.history[0]["loss"], res.history[-1]["loss"]
        for h in res.history:
            print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
                  f"lr {h.get('lr', 0):.2e} gnorm {h.get('grad_norm', 0):.2f}")
        print(f"{res.steps_per_s:.2f} steps/s | loss {first:.3f} -> "
              f"{last:.3f} ({(1 - last/first)*100:.1f}% drop)")

        # resume from the mid-run checkpoint and verify continuation works
        names = checkpoint.list_checkpoints(ckroot)
        mid = [c for c in names if not c.endswith("final")][0]
        tree = checkpoint.restore(ckroot, mid, like={"params": res.params})
        res2 = train(model, data, dataclasses.replace(tc, n_steps=5,
                                                      log_every=1),
                     params=tree["params"], start_step=args.steps // 2)
        print(f"resumed {mid}: 5 more steps, "
              f"loss {res2.history[-1]['loss']:.4f}")

    if not last < 0.8 * first:
        raise SystemExit(f"loss did not drop enough: {first} -> {last}")
    print("OK")


if __name__ == "__main__":
    main()
