"""End-to-end serving driver (deliverable b): the paper's full production
deployment, §3.3/§4.3, serving a batch of CV-parse requests.

    PYTHONPATH=src python examples/serve_parallel_pipeline.py \
        [--docs 40] [--replicas 3] [--fail-rate 0.08]

What it stands up, in the paper's startup order (supervisord priorities):
    0  tika            text extraction
    1  bert            sentence encoder + sectioning classifier
    2  5x section PaaS each with N replicas (1 backup) behind an
                       NGINX-style round-robin balancer, fault-injected
    3  cv_parser       the front-end that fans out in parallel

Then it serves a corpus with concurrent clients, kills a replica mid-run
to show failover (max_fails/fail_timeout/backup promotion), and prints
Table-6-style stage statistics and the parallel-vs-sequential comparison.

With ``--lm`` (default on) a slot-native LM PaaS joins the deployment:
two engine replicas behind a least-loaded balancer, each running the
mixed-length continuous-batching engine with an SLO-aware scheduler —
the LM analogue of the paper's per-section NER services.
"""
from __future__ import annotations

import argparse
import random
import statistics
import time
from concurrent.futures import ThreadPoolExecutor

import jax

from repro.core import cvdata, router
from repro.core.balancer import deploy
from repro.core.parallel import ParallelDispatcher
from repro.core.pipeline import CVParser, NERModel
from repro.core.services import Replica, Service
from repro.core.supervisor import Supervisor


def build_deployment(n_replicas: int, fail_rate: float):
    """The paper's cluster: every PaaS on `n_replicas` machines (last one
    backup), upstreamed behind a balancer, under a supervisor."""
    sup = Supervisor()
    sup.add(Service("tika", replicas=[Replica("tika/0", lambda p: p)],
                    priority=0))
    sup.add(Service("bert", replicas=[Replica("bert/0", lambda p: p)],
                    priority=1, depends_on=("tika",)))

    ks = jax.random.split(jax.random.key(0), len(router.ROUTES))
    services = {}
    for i, name in enumerate(router.ROUTES):
        ner = NERModel.create(name, ks[i])
        reps = [Replica(f"{name}/{r}", ner,
                        backup=(r == n_replicas - 1 and n_replicas > 1),
                        fail_rate=fail_rate)
                for r in range(n_replicas)]
        svc = Service(name, replicas=reps, priority=2, depends_on=("bert",))
        deploy(svc, max_fails=3, fail_timeout=2.0)
        services[name] = sup.add(svc)

    parser = CVParser.create(
        jax.random.key(1), services=services,
        dispatcher=ParallelDispatcher(mode="thread", max_workers=16,
                                      rng=random.Random(7)))
    sup.add(Service("cv_parser", replicas=[Replica("cv/0", parser.parse)],
                    priority=3, depends_on=tuple(services)))
    return sup, parser, services


def run_lm_paas(sup: Supervisor) -> None:
    """Slot-native LM serving as one more PaaS under the supervisor:
    2 engine replicas, least-loaded upstream, deadline scheduling."""
    import dataclasses

    from repro.configs.base import get_config
    from repro.models.model import build_model
    from repro.serve.service import make_lm_service

    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jax.numpy.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(9))
    svc = make_lm_service("lm_summarizer", model, params, n_replicas=2,
                          batch_size=2, max_seq=64, policy="deadline",
                          balancer_policy="least_loaded", with_backup=False,
                          supervisor=sup, priority=2)
    svc.start()

    rng = random.Random(11)
    lat = []
    for i in range(6):
        prompt = [rng.randrange(2, cfg.vocab_size)
                  for _ in range(rng.choice([5, 9, 13]))]
        out = svc({"prompt": prompt, "max_new_tokens": 4,
                   "deadline_s": time.perf_counter() + 30.0})
        lat.append(out["latency_s"])
    print(f"\nLM PaaS: served 6 mixed-length prompts, "
          f"p50 {sorted(lat)[3]*1e3:.0f} ms")
    for rep in svc.replicas:
        eng = rep.handler.scheduler.engine
        print(f"  {rep.name}: {eng.metrics}")
    st = sup.status()["lm_summarizer"]
    print(f"  supervisor: {st['state']} healthy={st['healthy_replicas']} "
          f"upstream={st['upstream']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=40)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--fail-rate", type=float, default=0.08)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--lm", action=argparse.BooleanOptionalAction,
                    default=True, help="stand up the LM PaaS stage too")
    args = ap.parse_args()

    sup, parser, services = build_deployment(args.replicas, args.fail_rate)
    order = sup.start_all()
    print("startup order:", " -> ".join(order))

    rng = random.Random(3)
    docs = [cvdata.make_document(rng) for _ in range(args.docs)]
    parser.parse(docs[0])                       # warm compile caches

    # -------------------------------------------------- serve concurrently
    cv = sup.services["cv_parser"]
    stage_acc: dict = {}
    t0 = time.perf_counter()
    kill_at = args.docs // 3

    def request(i_doc):
        i, doc = i_doc
        if i == kill_at:       # outage mid-run: first work_experience primary
            services["work_experience"].replicas[0].set_up(False)
            print(f"  !! killed work_experience/0 at request {i}")
        out = cv(doc)
        for k, v in out["timings"].items():
            stage_acc.setdefault(k, []).append(v)
        return out

    with ThreadPoolExecutor(max_workers=args.clients) as pool:
        results = list(pool.map(request, enumerate(docs)))
    wall = time.perf_counter() - t0

    # ------------------------------------------------------------- report
    print(f"\nserved {len(results)} CVs in {wall:.2f}s "
          f"({len(results)/wall:.1f} req/s, {args.clients} clients)")
    print("\nstage timings (ms) — the paper's Table 6 layout:")
    print(f"{'stage':20s} {'mean':>8s} {'p50':>8s} {'p75':>8s} {'max':>8s}")
    for k in ("tika", "sectioning", "bert", "parallel_services", "total"):
        v = sorted(stage_acc[k])
        print(f"{k:20s} {statistics.mean(v)*1e3:8.1f} "
              f"{v[len(v)//2]*1e3:8.1f} {v[3*len(v)//4]*1e3:8.1f} "
              f"{v[-1]*1e3:8.1f}")

    d = results[-1]["dispatch"]
    print(f"\nlast request: parallel dispatch {d.total_s*1e3:.1f} ms vs "
          f"sequential-equivalent {d.sequential_equivalent_s*1e3:.1f} ms "
          f"({d.speedup:.2f}x)")

    print("\nbalancer state after the injected outage:")
    for name, svc in services.items():
        b = svc.balancer
        served = b.stats["served"]
        print(f"  {name:22s} served={served:3d} "
              f"failovers={b.stats['failovers']:2d} "
              f"backup_served={b.stats['backup_served']:2d}")
    we = services["work_experience"]
    assert we.balancer.stats["served"] == args.docs + 1, "lost requests"
    print("\nOK — zero lost requests through the outage.")

    if args.lm:
        run_lm_paas(sup)


if __name__ == "__main__":
    main()
