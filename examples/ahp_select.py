"""AHP substrate selection on YOUR measurements (deliverable b).

    PYTHONPATH=src python examples/ahp_select.py

Reproduces the paper's Tables 3/4/5 from its published Table 2 data, then
re-runs the same methodology live against three in-process executor
backends (the Falcon/FastApi/Flask analogue this container can host) and
prints which backend the AHP selects per scenario.
"""
from __future__ import annotations

from repro.core.ahp import (PAPER_RESULTS, reproduce_paper_tables)


def main() -> None:
    print("== Paper data -> Tables 3/4/5 ==")
    for scenario, res in reproduce_paper_tables().items():
        print(f"\n-- {scenario} (paper: "
              f"{ {k: f'{v*100:.1f}%' for k, v in PAPER_RESULTS[scenario].items()} })")
        print(res.table())
        print(f"consistency ratios: "
              f"{ {k: round(v, 4) for k, v in res.consistency.items()} }")

    print("\n== Live re-run on executor backends ==")
    from benchmarks import bench_framework
    from benchmarks.report import Report
    bench_framework.run(Report(verbose=True))


if __name__ == "__main__":
    main()
