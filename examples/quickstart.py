"""Quickstart — the three layers of the framework in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-4b]

1. AHP substrate selection (the paper's §3.1/§4.1) on the paper's data.
2. One CV parsed end-to-end through the parallel PaaS pipeline (§4.2).
3. One forward + one train step of an assigned architecture (reduced
   config) through the model zoo the serving layer deploys.
"""
from __future__ import annotations

import argparse
import random

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.core import cvdata
from repro.core.ahp import reproduce_paper_tables
from repro.core.pipeline import CVParser
from repro.models.model import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    args = ap.parse_args()

    # 1 ---------------------------------------------------------------- AHP
    print("== 1. AHP framework selection (paper Tables 3-5) ==")
    for scenario, res in reproduce_paper_tables().items():
        (best, score), *_ = res.ranking()
        print(f"  {scenario:32s} -> {best} ({score*100:.1f}%)")

    # 2 ----------------------------------------------------------- pipeline
    print("\n== 2. CV-parser pipeline (parallel PaaS fan-out) ==")
    parser = CVParser.create(jax.random.key(0))
    doc = cvdata.make_document(random.Random(42))
    out = parser.parse(doc)
    for svc, fields in out["fields"].items():
        print(f"  {svc:22s} {len(fields):2d} entities "
              f"({out['dispatch'].per_call_s[svc]*1e3:.1f} ms)")
    t = out["timings"]
    print(f"  stages: tika={t['tika']*1e3:.1f}ms "
          f"bert={t['bert']*1e3:.1f}ms sect={t['sectioning']*1e3:.1f}ms "
          f"services={t['parallel_services']*1e3:.1f}ms "
          f"total={t['total']*1e3:.1f}ms")

    # 3 ------------------------------------------------------------- model
    print(f"\n== 3. Model zoo: {args.arch} (reduced) ==")
    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    n = sum(x.size for x in jax.tree.leaves(params))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(2), (B, S + 1), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    specs = model.input_specs  # noqa: B018 — part of the public API tour
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                          cfg.dtype)
    if cfg.frontend == "audio":
        batch["frames"] = jnp.zeros((B, cfg.n_frames, cfg.d_model),
                                    cfg.dtype)
    loss, metrics = jax.jit(lambda p, b: model.train_loss(p, b, None))(
        params, batch)
    print(f"  {n/1e6:.2f}M params | train loss {float(loss):.3f} | "
          f"metrics: {sorted(metrics)}")
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, None))(
        params, {k: (v[:, :-1] if k == 'tokens' else v)
                 for k, v in batch.items()})
    print(f"  prefill logits {logits.shape} | cache leaves: "
          f"{len(jax.tree.leaves(cache))}")
    print("\nOK — see examples/serve_parallel_pipeline.py for the "
          "end-to-end serving driver.")


if __name__ == "__main__":
    main()
