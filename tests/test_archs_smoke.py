"""Per-architecture smoke tests: REDUCED variant of each assigned arch runs
one train step + prefill + decode on CPU with finite outputs and the right
shapes (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.model import build_model

B, S = 2, 32


def batch_for(cfg, rng):
    b = {"tokens": jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        b["tokens"] = b["tokens"][:, : S - cfg.n_patches + 1]
        b["patch_embeds"] = jax.random.normal(
            rng, (B, cfg.n_patches, cfg.d_model), cfg.dtype)
    if cfg.frontend == "audio":
        b["frames"] = jax.random.normal(
            rng, (B, cfg.n_frames, cfg.d_model), cfg.dtype)
    return b


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_arch_train_prefill_decode(arch, rng):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))

    loss, metrics = jax.jit(m.train_loss)(params, batch_for(cfg, rng))
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0

    pre = batch_for(cfg, rng)
    pre["tokens"] = pre["tokens"][:, :-1]
    logits, cache = jax.jit(m.prefill)(params, pre)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))

    cache64 = m.init_cache(B, 64)
    tok = jnp.ones((B, 1), jnp.int32)
    lg, cache64 = jax.jit(m.decode_step)(params, tok, cache64, jnp.int32(32))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(lg.astype(jnp.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full-size config matches the assigned table exactly."""
    cfg = get_config(arch)
    table = {
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == table
    assert cfg.source, "every config must cite its source"


def test_moe_param_counts_roughly_match_names():
    grok = get_config("grok-1-314b")
    assert 250e9 < grok.n_params() < 380e9
    kimi = get_config("kimi-k2-1t-a32b")
    assert 0.8e12 < kimi.n_params() < 1.3e12
    assert 15e9 < kimi.n_active_params() < 50e9      # "A32B"
    nem = get_config("nemotron-4-340b")
    assert 300e9 < nem.n_params() < 380e9


def test_decode_is_causal_consistent_with_prefill():
    """Greedy decode after prefill matches teacher-forced next-token
    argmax from a longer prefill (KV-cache correctness)."""
    cfg = get_config("qwen3-4b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0,
                              cfg.vocab_size)
    # full prefill of 16 tokens -> logits for token 17
    full_logits, _ = m.prefill(params, {"tokens": toks})
    # prefill 15, then decode token 16 against capacity-16 cache
    l15, cache15 = m.prefill(params, {"tokens": toks[:, :15]})
    cache = m.init_cache(1, 16)
    for key in cache:
        pref = cache15[key]
        if cache[key].ndim >= 3 and pref.shape[2] == 15 and \
                cache[key].shape[2] == 16:
            cache[key] = jax.lax.dynamic_update_slice_in_dim(
                cache[key], pref.astype(cache[key].dtype), 0, axis=2)
        else:
            cache[key] = pref
    lg, _ = m.decode_step(params, toks[:, 15:16], cache, jnp.int32(15))
    assert int(jnp.argmax(lg[0, 0])) == int(jnp.argmax(full_logits[0, 0]))
