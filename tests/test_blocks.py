"""BlockPool allocator: alloc/free contracts, scratch reservation,
double-ownership as a property, fragmentation over recycle cycles — and
the engine-level edge cases: pool exhaustion mid-decode (park/resume)
and preemption when every active slot stalls."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.blocks import SCRATCH_BLOCK, BlockPool, blocks_for_tokens
from repro.serve.engine import Request, ServingEngine


# ------------------------------------------------------------- pure pool
def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2
    assert blocks_for_tokens(160, 16) == 10


def test_alloc_free_roundtrip():
    pool = BlockPool(8, 16)
    assert pool.total == 7                   # block 0 is scratch
    got = pool.alloc(3, owner="a")
    assert got is not None and len(got) == 3
    assert SCRATCH_BLOCK not in got
    assert pool.used == 3 and pool.available == 4
    assert all(pool.owner_of(b) == "a" for b in got)
    pool.free(got, owner="a")
    assert pool.used == 0 and pool.available == 7


def test_alloc_is_all_or_nothing():
    pool = BlockPool(4, 8)                   # 3 allocatable
    assert pool.alloc(4, owner="x") is None
    assert pool.available == 3               # nothing was taken
    assert pool.alloc(3, owner="x") is not None
    assert pool.alloc(1, owner="y") is None


def test_free_validates_ownership():
    pool = BlockPool(8, 16)
    a = pool.alloc(2, owner="a")
    with pytest.raises(ValueError, match="owned by"):
        pool.free(a, owner="b")
    pool.free(a, owner="a")
    with pytest.raises(ValueError, match="not allocated"):
        pool.free(a, owner="a")              # double free


def test_scratch_block_never_handed_out():
    pool = BlockPool(5, 8)
    got = pool.alloc(4, owner="x")           # drain the whole pool
    assert got is not None and SCRATCH_BLOCK not in got
    assert pool.available == 0


def test_occupancy_and_stats():
    pool = BlockPool(11, 4)
    pool.alloc(5, owner=1)
    assert pool.occupancy == pytest.approx(0.5)
    s = pool.stats()
    assert s["total"] == 10 and s["used"] == 5 and s["block_size"] == 4


def test_no_fragmentation_after_many_recycle_cycles():
    """Blocks are interchangeable: after arbitrary interleaved alloc/free
    churn, a full-pool allocation still succeeds — there is no external
    fragmentation to compact."""
    pool = BlockPool(17, 8)                  # 16 allocatable
    held = {}
    for cycle in range(50):
        n = 1 + (cycle * 7) % 5
        got = pool.alloc(n, owner=cycle)
        while got is None:                   # free oldest holders, retry
            victim = min(held)
            pool.free(held.pop(victim), owner=victim)
            got = pool.alloc(n, owner=cycle)
        held[cycle] = got
        if cycle % 3 == 2 and held:
            victim = max(held)
            pool.free(held.pop(victim), owner=victim)
    for owner, blocks in held.items():
        pool.free(blocks, owner=owner)
    assert pool.available == pool.total
    full = pool.alloc(pool.total, owner="all")
    assert full is not None and len(set(full)) == pool.total


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), st.integers(min_value=0,
                                                         max_value=6)),
                    min_size=0, max_size=60))
def test_property_no_block_double_owned(ops):
    """Whatever alloc/free sequence runs, no physical block is ever owned
    by two owners at once, the scratch block is never handed out, and
    used + available always equals the pool total."""
    pool = BlockPool(13, 4)
    held: dict = {}
    tag = 0
    for is_alloc, n in ops:
        if is_alloc:
            tag += 1
            got = pool.alloc(n, owner=tag)
            if got is not None:
                assert SCRATCH_BLOCK not in got
                for b in got:
                    for other_blocks in held.values():
                        assert b not in other_blocks   # never double-owned
                held[tag] = got
            else:
                assert n > pool.available or n > 0 and not pool.available
        elif held:
            victim = sorted(held)[n % len(held)]
            pool.free(held.pop(victim), owner=victim)
        assert pool.used + pool.available == pool.total
        assert pool.used == sum(len(v) for v in held.values())


# ---------------------------------------------- prefix sharing + CoW (pool)
def test_acquire_refcount_and_shared_accounting():
    pool = BlockPool(8, 4)
    (b,) = pool.alloc(1, owner="a")
    assert pool.register(b, pool.ROOT, (1, 2, 3, 4)) == b
    pool.acquire(b, owner="b")
    assert pool.refcount(b) == 2 and not pool.writable(b)
    assert pool.used == 1                     # shared block counts ONCE
    assert pool.shared == 1
    with pytest.raises(ValueError, match="already holds"):
        pool.acquire(b, owner="a")
    with pytest.raises(ValueError, match="free block"):
        pool.acquire(99, owner="c")
    pool.free([b], owner="a")
    assert pool.refcount(b) == 1 and pool.writable(b)
    # still resident: stays indexed
    assert pool.lookup(pool.ROOT, (1, 2, 3, 4)) == b
    pool.free([b], owner="b")
    assert pool.refcount(b) == 0 and pool.available == pool.total
    # freed: the entry survives as a CACHED block until the memory is
    # actually reused — a sequential same-prefix request can revive it
    assert pool.lookup(pool.ROOT, (1, 2, 3, 4)) == b
    assert pool.cached == 1
    pool.acquire(b, owner="c")                # revive: back to refcount 1
    assert pool.refcount(b) == 1 and pool.used == 1 and pool.cached == 0
    assert pool.lookup(pool.ROOT, (1, 2, 3, 4)) == b
    pool.free([b], owner="c")
    got = pool.alloc(pool.total, owner="d")   # recycling evicts the entry
    assert got is not None
    assert pool.lookup(pool.ROOT, (1, 2, 3, 4)) is None
    assert pool.cached == 0
    pool.check()


def test_prefix_index_match_full_partial_and_cap():
    pool = BlockPool(10, 4)
    toks = list(range(5, 17))                 # 12 tokens = 3 full blocks
    blocks = pool.alloc(3, owner="src")
    parent = pool.ROOT
    for i, b in enumerate(blocks):
        parent = pool.register(b, parent, tuple(toks[i * 4:(i + 1) * 4]))
        assert parent == b
    # a duplicate registration resolves to the canonical block
    (dup,) = pool.alloc(1, owner="dup")
    assert pool.register(dup, pool.ROOT, tuple(toks[:4])) == blocks[0]
    pool.free([dup], owner="dup")
    # full-chunk walk
    got, m = pool.match(toks, 12)
    assert got == blocks and m == 12
    # cap at P-1 turns the last chunk into a partial-tail share
    got, m = pool.match(toks, 11)
    assert got == blocks and m == 11
    # diverging token stops the walk at the block boundary
    other = toks[:4] + [99] + toks[5:]
    got, m = pool.match(other, len(other) - 1)
    assert got == blocks[:1] and m == 4
    # prepare_write below the registered extent drops the entry
    pool.prepare_write(blocks[2], 1)
    assert pool.lookup(blocks[1], tuple(toks[8:12])) is None
    got, m = pool.match(toks, 12)
    assert got == blocks[:2] and m == 8


def test_prepare_write_refuses_shared_block():
    pool = BlockPool(6, 4)
    (b,) = pool.alloc(1, owner="a")
    pool.acquire(b, owner="b")
    with pytest.raises(ValueError, match="shared"):
        pool.prepare_write(b, 0)


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                              st.integers(min_value=0, max_value=5),
                              st.integers(min_value=1, max_value=14)),
                    min_size=1, max_size=120))
def test_property_sharing_churn_invariants(ops):
    """Random admit/append/free churn with prefix sharing on, mirroring
    the engine's block bookkeeping against a content model. Invariants
    checked after EVERY operation:

    * refcounts never negative, holders unique (``pool.check``);
    * no block is ever written while shared — CoW first (the state
      machine refuses to write unless ``pool.writable``);
    * CoW never mutates the original: the copy gets the writes;
    * pool accounting sums to the pool (used + available == total), a
      shared block counted once;
    * the content model agrees with every slot's logical tokens — the
      real no-cross-sequence-corruption property.
    """
    BS = 4
    pool = BlockPool(13, BS)
    contents: dict = {}           # phys block -> list of tokens written
    slots: dict = {}              # sid -> {tokens, len, blocks}
    next_sid = 0

    def check_all():
        pool.check()
        assert pool.used + pool.available == pool.total
        holders: dict = {}
        for sid, s in slots.items():
            assert len(set(s["blocks"])) == len(s["blocks"])
            for b in s["blocks"]:
                holders[b] = holders.get(b, 0) + 1
            # content model == logical tokens (the corruption check)
            for pos in range(s["len"]):
                b = s["blocks"][pos // BS]
                assert contents[b][pos % BS] == s["tokens"][pos], \
                    (sid, pos, b)
        for b, n in holders.items():
            assert pool.refcount(b) == n, (b, n, pool.refcount(b))

    def write(sid, token):
        """The engine's grow-or-park + scatter, against the model."""
        s = slots[sid]
        pos = s["len"]
        bi = pos // BS
        if bi >= len(s["blocks"]):
            got = pool.alloc(1, owner=sid)
            if got is None:
                return False                       # parked
            s["blocks"].extend(got)
            contents[got[0]] = [None] * BS
        else:
            b = s["blocks"][bi]
            if not pool.writable(b):               # CoW before writing
                got = pool.alloc(1, owner=sid)
                if got is None:
                    return False
                contents[got[0]] = list(contents[b])   # device copy
                pool.free([b], owner=sid)
                assert pool.refcount(b) >= 1       # original survives
                s["blocks"][bi] = got[0]
        b = s["blocks"][bi]
        assert pool.writable(b)                    # never write shared
        pool.prepare_write(b, pos % BS)
        contents[b][pos % BS] = token
        s["len"] = pos + 1
        s["tokens"].append(token)
        return True

    for kind, pick, val in ops:
        if kind == 0:
            # admit: prompt drawn from a tiny vocab so prefixes collide
            prompt = [(val * (i + 3)) % 5 for i in range(val)]
            blocks, m = pool.match(prompt, len(prompt) - 1)
            need = blocks_for_tokens(len(prompt), BS) - len(blocks)
            if pool.available < need:
                continue                           # shed
            sid = next_sid
            next_sid += 1
            for b in blocks:
                pool.acquire(b, owner=sid)
            slots[sid] = {"tokens": list(prompt[:m]), "len": m,
                          "blocks": list(blocks)}
            ok = True
            for t in prompt[m:]:                   # catch-up writes
                if not write(sid, t):
                    ok = False
                    break
            if ok and m == 0:
                # a plain admission registers its prompt blocks, chained
                # through the canonical parent like the engine does
                parent = pool.ROOT
                for i, b in enumerate(slots[sid]["blocks"]):
                    if parent is False:
                        break
                    parent = pool.register(
                        b, parent, tuple(prompt[i * BS:(i + 1) * BS]))
                    if parent is None:
                        parent = False
        elif kind == 1 and slots:                  # append (decode step)
            sid = sorted(slots)[pick % len(slots)]
            write(sid, val % 5)
        elif kind == 2 and slots:                  # retire / preempt
            sid = sorted(slots)[pick % len(slots)]
            s = slots.pop(sid)
            pool.free(s["blocks"], owner=sid)
        check_all()

    for sid, s in list(slots.items()):
        pool.free(s["blocks"], owner=sid)
    assert pool.available == pool.total
    # entries survive frees as cached blocks; recycling the whole pool
    # evicts every one of them
    assert pool.stats()["indexed"] == pool.cached
    pool.check()
    full = pool.alloc(pool.total, owner="sweep")
    assert full is not None
    assert pool.stats()["indexed"] == 0


# ------------------------------------------------- engine-level edge cases
@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reqs(cfg, lens, max_new=4, seed=1):
    rng = jax.random.key(seed)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=max_new,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist()))
    return out


def test_exhaustion_mid_decode_parks_then_resumes(stack):
    """A slot that cannot grow parks (no token emitted, state intact)
    and resumes after another request frees blocks — output identical to
    an uncontended run."""
    cfg, model, params = stack
    # pool of 5: two 1-block prompts admit (+1 growth block spare); the
    # younger slot must park when both cross their block boundary
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        paged=True, block_size=8, num_blocks=6)
    reqs = _reqs(cfg, [10, 12, 9], max_new=12)
    done = eng.run(list(reqs))
    assert len(done) == 3
    assert eng.metrics["parked_slot_steps"] > 0      # exhaustion was hit
    assert eng.pool.available == eng.pool.total      # all blocks returned
    roomy = ServingEngine(model, params, batch_size=1, max_seq=64,
                          paged=True, block_size=8)
    for r in reqs:
        (d,) = roomy.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                  max_new_tokens=12)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_total_stall_preempts_newest_and_completes(stack):
    """When EVERY active slot needs a block and none is free, the newest
    admission is evicted (recompute-on-resume) so the oldest advances;
    the evicted request still completes correctly afterwards."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=4, num_blocks=4)  # 3 blocks
    reqs = _reqs(cfg, [4, 4], max_new=8)
    done = eng.run(list(reqs))
    assert len(done) == 2
    assert eng.metrics["preemptions"] >= 1
    assert sum(r.preemptions for r in reqs) >= 1
    assert all(len(r.out_tokens) == 8 for r in reqs)
    assert eng.pool.available == eng.pool.total
    roomy = ServingEngine(model, params, batch_size=1, max_seq=64,
                          paged=True, block_size=4)
    for r in reqs:
        (d,) = roomy.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                  max_new_tokens=8)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_single_slot_owning_whole_pool_is_truncated(stack):
    """One request that outgrows the entire pool cannot be preempted
    (nothing else holds blocks): it finishes capacity-truncated instead
    of deadlocking."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=1, max_seq=64,
                        paged=True, block_size=4, num_blocks=3)  # 2 blocks
    (req,) = _reqs(cfg, [6], max_new=50)
    (done,) = eng.run([req])
    # 6 prompt tokens + decode until both blocks are full (8 positions)
    assert len(done.out_tokens) < 50
    assert eng.active == 0 and eng.waiting == 0
    assert eng.pool.available == eng.pool.total


def test_admission_gated_on_blocks_not_slots(stack):
    """Plenty of free slots but a near-empty pool: admission takes only
    what the pool can hold (plus growth reserve), in order."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=8, max_seq=64,
                        paged=True, block_size=8, num_blocks=5)  # 4 blocks
    reqs = _reqs(cfg, [8, 8, 8, 8], max_new=2)
    admitted = eng.add_requests(list(reqs))
    # 4 blocks: 3 x 1-block prompts fit with 1 reserve; the 4th must wait
    assert admitted == 3
    assert len(eng.free_slots()) == 5
    done = eng.run(reqs[admitted:])
    assert len(done) == 4 - admitted or eng.metrics["completed"] == 4


def test_pool_state_consistent_with_slots(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=4, max_seq=64,
                        paged=True, block_size=8)
    reqs = _reqs(cfg, [5, 20, 9], max_new=2)
    eng.add_requests(list(reqs))
    assert eng.pool.used == sum(len(b) for b in eng.slot_blocks)
    assert eng.pool.used == 1 + 3 + 2        # ceil(5/8), ceil(20/8), ceil(9/8)
    for slot, blocks in enumerate(eng.slot_blocks):
        for b in blocks:
            assert eng.pool.owner_of(b) == slot
    stats = eng.pool_stats()
    assert stats["paged"] and stats["used"] == 6
    assert 0.0 < eng.memory_pressure() < 1.0
