"""BlockPool allocator: alloc/free contracts, scratch reservation,
double-ownership as a property, fragmentation over recycle cycles — and
the engine-level edge cases: pool exhaustion mid-decode (park/resume)
and preemption when every active slot stalls."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.blocks import SCRATCH_BLOCK, BlockPool, blocks_for_tokens
from repro.serve.engine import Request, ServingEngine


# ------------------------------------------------------------- pure pool
def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2
    assert blocks_for_tokens(160, 16) == 10


def test_alloc_free_roundtrip():
    pool = BlockPool(8, 16)
    assert pool.total == 7                   # block 0 is scratch
    got = pool.alloc(3, owner="a")
    assert got is not None and len(got) == 3
    assert SCRATCH_BLOCK not in got
    assert pool.used == 3 and pool.available == 4
    assert all(pool.owner_of(b) == "a" for b in got)
    pool.free(got, owner="a")
    assert pool.used == 0 and pool.available == 7


def test_alloc_is_all_or_nothing():
    pool = BlockPool(4, 8)                   # 3 allocatable
    assert pool.alloc(4, owner="x") is None
    assert pool.available == 3               # nothing was taken
    assert pool.alloc(3, owner="x") is not None
    assert pool.alloc(1, owner="y") is None


def test_free_validates_ownership():
    pool = BlockPool(8, 16)
    a = pool.alloc(2, owner="a")
    with pytest.raises(ValueError, match="owned by"):
        pool.free(a, owner="b")
    pool.free(a, owner="a")
    with pytest.raises(ValueError, match="not allocated"):
        pool.free(a, owner="a")              # double free


def test_scratch_block_never_handed_out():
    pool = BlockPool(5, 8)
    got = pool.alloc(4, owner="x")           # drain the whole pool
    assert got is not None and SCRATCH_BLOCK not in got
    assert pool.available == 0


def test_occupancy_and_stats():
    pool = BlockPool(11, 4)
    pool.alloc(5, owner=1)
    assert pool.occupancy == pytest.approx(0.5)
    s = pool.stats()
    assert s["total"] == 10 and s["used"] == 5 and s["block_size"] == 4


def test_no_fragmentation_after_many_recycle_cycles():
    """Blocks are interchangeable: after arbitrary interleaved alloc/free
    churn, a full-pool allocation still succeeds — there is no external
    fragmentation to compact."""
    pool = BlockPool(17, 8)                  # 16 allocatable
    held = {}
    for cycle in range(50):
        n = 1 + (cycle * 7) % 5
        got = pool.alloc(n, owner=cycle)
        while got is None:                   # free oldest holders, retry
            victim = min(held)
            pool.free(held.pop(victim), owner=victim)
            got = pool.alloc(n, owner=cycle)
        held[cycle] = got
        if cycle % 3 == 2 and held:
            victim = max(held)
            pool.free(held.pop(victim), owner=victim)
    for owner, blocks in held.items():
        pool.free(blocks, owner=owner)
    assert pool.available == pool.total
    full = pool.alloc(pool.total, owner="all")
    assert full is not None and len(set(full)) == pool.total


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.booleans(), st.integers(min_value=0,
                                                         max_value=6)),
                    min_size=0, max_size=60))
def test_property_no_block_double_owned(ops):
    """Whatever alloc/free sequence runs, no physical block is ever owned
    by two owners at once, the scratch block is never handed out, and
    used + available always equals the pool total."""
    pool = BlockPool(13, 4)
    held: dict = {}
    tag = 0
    for is_alloc, n in ops:
        if is_alloc:
            tag += 1
            got = pool.alloc(n, owner=tag)
            if got is not None:
                assert SCRATCH_BLOCK not in got
                for b in got:
                    for other_blocks in held.values():
                        assert b not in other_blocks   # never double-owned
                held[tag] = got
            else:
                assert n > pool.available or n > 0 and not pool.available
        elif held:
            victim = sorted(held)[n % len(held)]
            pool.free(held.pop(victim), owner=victim)
        assert pool.used + pool.available == pool.total
        assert pool.used == sum(len(v) for v in held.values())


# ------------------------------------------------- engine-level edge cases
@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reqs(cfg, lens, max_new=4, seed=1):
    rng = jax.random.key(seed)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=max_new,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist()))
    return out


def test_exhaustion_mid_decode_parks_then_resumes(stack):
    """A slot that cannot grow parks (no token emitted, state intact)
    and resumes after another request frees blocks — output identical to
    an uncontended run."""
    cfg, model, params = stack
    # pool of 5: two 1-block prompts admit (+1 growth block spare); the
    # younger slot must park when both cross their block boundary
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        paged=True, block_size=8, num_blocks=6)
    reqs = _reqs(cfg, [10, 12, 9], max_new=12)
    done = eng.run(list(reqs))
    assert len(done) == 3
    assert eng.metrics["parked_slot_steps"] > 0      # exhaustion was hit
    assert eng.pool.available == eng.pool.total      # all blocks returned
    roomy = ServingEngine(model, params, batch_size=1, max_seq=64,
                          paged=True, block_size=8)
    for r in reqs:
        (d,) = roomy.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                  max_new_tokens=12)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_total_stall_preempts_newest_and_completes(stack):
    """When EVERY active slot needs a block and none is free, the newest
    admission is evicted (recompute-on-resume) so the oldest advances;
    the evicted request still completes correctly afterwards."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=4, num_blocks=4)  # 3 blocks
    reqs = _reqs(cfg, [4, 4], max_new=8)
    done = eng.run(list(reqs))
    assert len(done) == 2
    assert eng.metrics["preemptions"] >= 1
    assert sum(r.preemptions for r in reqs) >= 1
    assert all(len(r.out_tokens) == 8 for r in reqs)
    assert eng.pool.available == eng.pool.total
    roomy = ServingEngine(model, params, batch_size=1, max_seq=64,
                          paged=True, block_size=4)
    for r in reqs:
        (d,) = roomy.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                  max_new_tokens=8)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_single_slot_owning_whole_pool_is_truncated(stack):
    """One request that outgrows the entire pool cannot be preempted
    (nothing else holds blocks): it finishes capacity-truncated instead
    of deadlocking."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=1, max_seq=64,
                        paged=True, block_size=4, num_blocks=3)  # 2 blocks
    (req,) = _reqs(cfg, [6], max_new=50)
    (done,) = eng.run([req])
    # 6 prompt tokens + decode until both blocks are full (8 positions)
    assert len(done.out_tokens) < 50
    assert eng.active == 0 and eng.waiting == 0
    assert eng.pool.available == eng.pool.total


def test_admission_gated_on_blocks_not_slots(stack):
    """Plenty of free slots but a near-empty pool: admission takes only
    what the pool can hold (plus growth reserve), in order."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=8, max_seq=64,
                        paged=True, block_size=8, num_blocks=5)  # 4 blocks
    reqs = _reqs(cfg, [8, 8, 8, 8], max_new=2)
    admitted = eng.add_requests(list(reqs))
    # 4 blocks: 3 x 1-block prompts fit with 1 reserve; the 4th must wait
    assert admitted == 3
    assert len(eng.free_slots()) == 5
    done = eng.run(reqs[admitted:])
    assert len(done) == 4 - admitted or eng.metrics["completed"] == 4


def test_pool_state_consistent_with_slots(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=4, max_seq=64,
                        paged=True, block_size=8)
    reqs = _reqs(cfg, [5, 20, 9], max_new=2)
    eng.add_requests(list(reqs))
    assert eng.pool.used == sum(len(b) for b in eng.slot_blocks)
    assert eng.pool.used == 1 + 3 + 2        # ceil(5/8), ceil(20/8), ceil(9/8)
    for slot, blocks in enumerate(eng.slot_blocks):
        for b in blocks:
            assert eng.pool.owner_of(b) == slot
    stats = eng.pool_stats()
    assert stats["paged"] and stats["used"] == 6
    assert 0.0 < eng.memory_pressure() < 1.0
