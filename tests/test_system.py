"""End-to-end behaviour of the paper's system: full CV-parser pipeline
under the supervisor with HA replicas, failover during traffic, parallel
vs sequential equivalence, and the trained-NER accuracy path."""
import random

import jax
import numpy as np
import pytest

from repro.core import cvdata, router
from repro.core.balancer import deploy
from repro.core.parallel import ParallelDispatcher
from repro.core.pipeline import CVParser, NERModel
from repro.core.services import Replica, Service, ServiceError
from repro.core.supervisor import Supervisor


@pytest.fixture(scope="module")
def parser():
    return CVParser.create(rng=jax.random.key(42))


@pytest.fixture(scope="module")
def corpus():
    return cvdata.make_corpus(8, seed=1)


def test_parse_produces_all_sections_and_timings(parser, corpus):
    out = parser.parse(corpus[0])
    assert set(out["fields"]) == set(router.ROUTES)
    for key in ("tika", "sectioning", "bert", "parallel_services", "total"):
        assert out["timings"][key] >= 0
    assert out["timings"]["total"] >= out["timings"]["parallel_services"]


def test_parallel_and_sequential_agree(parser, corpus):
    seq = ParallelDispatcher(mode="sequential")
    doc = corpus[1]
    out_par = parser.parse(doc)["fields"]
    parser_seq = CVParser(parser.extractor, parser.encoder_cfg,
                          parser.encoder_params, parser.classifier_params,
                          parser.services, seq, parser.tokenizer)
    out_seq = parser_seq.parse(doc)["fields"]
    assert out_par == out_seq


def test_unsupported_mime_rejected(parser):
    doc = cvdata.Document(mime="exe")
    with pytest.raises(ValueError, match="unsupported mime"):
        parser.parse(doc)


def test_ha_failover_keeps_parsing(corpus):
    """Kill the primary replicas of one PaaS mid-traffic: the backup takes
    over and parsing continues (paper §3.3: zero-downtime deployment)."""
    parser = CVParser.create(rng=jax.random.key(7))
    name = "skills"
    ner = parser.services[name].replicas[0].handler
    svc = Service(name, replicas=[
        Replica(f"{name}/a", ner), Replica(f"{name}/b", ner),
        Replica(f"{name}/backup", ner, backup=True)])
    deploy(svc, max_fails=1)
    svc.start()
    parser.services[name] = svc

    out1 = parser.parse(corpus[2])
    svc.replicas[0].set_up(False)
    svc.replicas[1].set_up(False)          # both primaries down
    out2 = parser.parse(corpus[2])
    assert out1["fields"][name] == out2["fields"][name]
    assert svc.balancer.stats["backup_served"] > 0


def test_full_stack_under_supervisor(parser, corpus):
    sup = Supervisor()
    tika = Service("tika", replicas=[Replica("tika/0",
                                             parser.extractor.extract)],
                   priority=0)
    bert = Service("bert", replicas=[Replica("bert/0", lambda p: p)],
                   priority=1, depends_on=("tika",))
    sup.add(tika)
    sup.add(bert)
    for name, svc in parser.services.items():
        svc.priority = 2
        svc.depends_on = ("bert",)
        svc.started = False
        sup.add(svc)
    cv = Service("cv_parser", replicas=[Replica("cv/0", parser.parse)],
                 priority=3, depends_on=tuple(parser.services))
    sup.add(cv)
    order = sup.start_all()
    assert order[0] == "tika" and order[-1] == "cv_parser"
    out = cv(corpus[3])
    assert set(out["fields"]) == set(router.ROUTES)


def test_trained_ner_beats_chance():
    """Train one section NER on the synthetic corpus for a few steps and
    check token accuracy clearly beats majority-class guessing."""
    from repro.models import bilstm_lan
    from repro.core.cvdata import SERVICE_LABELS, HashTokenizer

    name = "education"
    labels = SERVICE_LABELS[name]
    ner = NERModel.create(name, jax.random.key(0))
    tok = HashTokenizer(4096)
    rng = random.Random(0)
    sents = [cvdata._sent(rng, name) for _ in range(256)]
    X = np.array([tok.pad(tok.encode(s.tokens), 16) for s in sents], np.int32)
    Y = np.array([[labels.index(l) for l in s.labels[:16]] +
                  [0] * (16 - len(s.labels[:16])) for s in sents], np.int32)
    M = (X != 0).astype(np.float32)

    # Train with the framework's own optimizer (AdamW + clip + cosine) —
    # plain SGD stalls at the majority class because the label-attention
    # logits start near zero (0.02-scale label embeddings).
    from repro.train import optimizer as opt

    c = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=120,
                        weight_decay=0.0)
    params = ner.params
    state = opt.init_state(params)

    @jax.jit
    def step(params, state):
        l, g = jax.value_and_grad(
            lambda p: bilstm_lan.loss(p, ner.cfg, X, Y, M))(params)
        params, state, _ = opt.apply_updates(params, g, state, c)
        return params, state, l

    for _ in range(120):
        params, state, l = step(params, state)
    pred = np.asarray(jax.jit(lambda p, x: bilstm_lan.predict(p, ner.cfg, x))
                      (params, X))
    acc = ((pred == Y) * M).sum() / M.sum()
    majority = max((Y[M > 0] == i).mean() for i in range(len(labels)))
    assert acc > majority + 0.15, (acc, majority)
    assert acc > 0.9
