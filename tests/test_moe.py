"""MoE dispatch: capacity math, routed-vs-dense equivalence at high
capacity, partial-expert decomposition (the EP invariant), aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models import moe

CFG = get_config("grok-1-314b").reduced()      # 4 experts, top-2


def setup(T=64, cf=8.0):
    import dataclasses
    cfg = dataclasses.replace(CFG, capacity_factor=cf)
    rng = jax.random.PRNGKey(0)
    p = moe.init_moe(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def dense_reference(x, p, cfg):
    """No-capacity reference: every token through its top-k experts."""
    w, ids, _ = moe.route(x, p["router"], cfg)
    E = cfg.n_experts
    out = jnp.zeros_like(x)
    for e in range(E):
        from repro.models.layers import act_fn
        pe = {k_: v[e] for k_, v in p.items() if k_ != "router"}
        if "w_gate" in pe:
            h = act_fn(cfg.act)(x @ pe["w_gate"]) * (x @ pe["w_in"])
        else:
            h = act_fn(cfg.act)(x @ pe["w_in"])
        ye = h @ pe["w_out"]
        gate = jnp.sum(jnp.where(ids == e, w, 0.0), axis=-1)
        out = out + ye * gate[:, None]
    return out


def test_high_capacity_matches_dense_reference():
    cfg, p, x = setup(cf=8.0)     # capacity >> need: nothing dropped
    out, _ = moe.moe_ffn_local(x, p, cfg)
    ref = dense_reference(x, p, cfg)
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               atol=2e-4, rtol=2e-4)


def test_expert_partition_sums_to_full():
    """EP invariant: sum of partial outputs over expert slices == full
    output (this is what the psum over the model axis computes)."""
    cfg, p, x = setup(cf=8.0)
    full, _ = moe.moe_ffn_local(x, p, cfg)
    half = cfg.n_experts // 2
    p1, _ = moe.moe_ffn_local(x, p, cfg, e0=0, E_loc=half)
    p2, _ = moe.moe_ffn_local(x, p, cfg, e0=half, E_loc=half)
    np.testing.assert_allclose(np.float32(p1 + p2), np.float32(full),
                               atol=2e-4, rtol=2e-4)


def test_capacity_drops_tokens_but_stays_finite():
    cfg, p, x = setup(T=128, cf=0.25)
    out, aux = moe.moe_ffn_local(x, p, cfg)
    assert np.all(np.isfinite(np.float32(out)))
    # with tight capacity, output differs from dense (tokens dropped)
    ref = dense_reference(x, p, cfg)
    assert not np.allclose(np.float32(out), np.float32(ref), atol=1e-3)


def test_aux_loss_penalizes_imbalance():
    cfg, p, x = setup()
    # uniform probabilities -> sum(me*ce) = 1/E -> aux ~ 1 * weight
    _, _, aux_bal = moe.route(x, p["router"] * 0.0, cfg)
    # collapsed router: every token to expert 0 -> aux ~ E * weight
    router0 = jnp.zeros_like(p["router"]).at[:, 0].set(1.0)
    _, ids, aux_col = moe.route(jnp.ones_like(x), router0, cfg)
    assert int(jnp.max(ids[:, 0])) == 0
    assert float(aux_col) > 2.0 * float(aux_bal)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=4))
def test_dispatch_tables_are_valid(seed):
    cfg, p, x = setup(T=32, cf=1.0)
    w, ids, _ = moe.route(x + seed, p["router"], cfg)
    C = moe.capacity(32, cfg)
    tok, gw = moe.dispatch_tables(ids, w, 0, cfg.n_experts, C)
    tok, gw = np.asarray(tok), np.asarray(gw)
    assert tok.shape == (cfg.n_experts, C)
    assert ((tok >= 0) & (tok <= 32)).all()           # 32 = pad id
    assert (gw >= 0).all() and (gw <= 1.0 + 1e-6).all()
    # each (expert, real-token) slot appears at most once
    for e in range(cfg.n_experts):
        real = tok[e][tok[e] < 32]
        assert len(set(real.tolist())) == len(real)


def test_capacity_rounding():
    cfg, _, _ = setup()
    c = moe.capacity(1024, cfg)
    assert c % 8 == 0
    assert c >= 1024 * cfg.top_k * cfg.capacity_factor / cfg.n_experts
