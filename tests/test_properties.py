"""Hypothesis property tests over system invariants (deliverable c)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.balancer import RoundRobinBalancer, deploy
from repro.core.services import Replica, Service, ServiceError
from repro.train import checkpoint


# ------------------------------------------------------------- balancer
@settings(max_examples=40, deadline=None)
@given(
    n_primaries=st.integers(min_value=1, max_value=5),
    n_requests=st.integers(min_value=1, max_value=60),
    fail_pattern=st.lists(st.booleans(), min_size=0, max_size=60),
)
def test_no_request_lost_while_any_replica_up(n_primaries, n_requests,
                                              fail_pattern):
    """Whatever transient-failure pattern the primaries show, the
    upstream never loses a request while the backup stays healthy —
    the paper's HA claim as an invariant."""
    fails = iter(fail_pattern + [False] * 1000)

    def flaky(payload):
        if next(fails):
            raise ServiceError("transient")
        return payload

    reps = [Replica(f"p{i}", flaky) for i in range(n_primaries)]
    reps.append(Replica("backup", lambda p: p, backup=True))
    clock = [0.0]
    bal = RoundRobinBalancer(reps, max_fails=3, fail_timeout=15.0,
                             clock=lambda: clock[0])
    # ServiceError raised by the handler is NOT retried by Replica
    # (it escapes), so count only balancer-level outcomes
    served = 0
    for i in range(n_requests):
        clock[0] += 0.01
        try:
            assert bal(i) == i
            served += 1
        except ServiceError:
            pytest.fail("request lost while backup healthy")
    assert served == n_requests


@settings(max_examples=30, deadline=None)
@given(n=st.integers(min_value=1, max_value=6),
       rounds=st.integers(min_value=1, max_value=10))
def test_round_robin_even_distribution(n, rounds):
    reps = [Replica(f"p{i}", lambda p: p) for i in range(n)]
    bal = RoundRobinBalancer(reps)
    for i in range(n * rounds):
        bal(i)
    counts = [r.calls for r in reps]
    assert max(counts) - min(counts) == 0       # perfectly even


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=100))
def test_backup_never_serves_while_primary_healthy(n_requests):
    reps = [Replica("p0", lambda p: p),
            Replica("b", lambda p: p, backup=True)]
    svc = Service("s", replicas=reps)
    svc.start()
    deploy(svc)
    for i in range(n_requests):
        svc(i)
    assert reps[1].calls == 0


# ------------------------------------------------------------ checkpoint
_leaf = st.tuples(
    st.sampled_from([np.float32, np.int32, np.float16]),
    st.lists(st.integers(min_value=1, max_value=7), min_size=0, max_size=3),
)


@settings(max_examples=20, deadline=None)
@given(st.dictionaries(
    st.text(alphabet="abcdef", min_size=1, max_size=6), _leaf,
    min_size=1, max_size=5),
    st.integers(min_value=16, max_value=4096))
def test_checkpoint_roundtrip_any_tree(tmp_path_factory, tree_spec,
                                       chunk_bytes):
    """save -> restore is the identity for arbitrary pytrees and chunk
    sizes (the GridFS design point: chunking never corrupts)."""
    root = tmp_path_factory.mktemp("ck")
    rng = np.random.default_rng(0)
    tree = {k: (rng.standard_normal(shape) * 10).astype(dt)
            for k, (dt, shape) in tree_spec.items()}
    checkpoint.save(root, "t", tree, chunk_bytes=chunk_bytes)
    back = checkpoint.restore(root, "t", like=tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), tree[k])


# ------------------------------------------------------------ vocab pad
@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=300_000))
def test_padded_vocab_invariants(v):
    import dataclasses

    from repro.configs.base import get_config
    from repro.models.model import padded_vocab

    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              vocab_size=v)
    vp = padded_vocab(cfg)
    assert vp >= v and vp % 128 == 0 and vp - v < 128
