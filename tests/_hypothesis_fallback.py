"""Minimal stand-in for ``hypothesis`` so the property-test modules stay
runnable (and meaningful) in environments without the dependency.

Implements exactly the surface this suite uses — ``given`` (positional
and keyword strategies, mixed with pytest fixtures), ``settings``
(``max_examples``; ``deadline`` ignored), and the ``strategies`` used in
the tests (integers, floats, booleans, lists, tuples, dictionaries,
text, sampled_from, composite). Draws are pseudo-random but seeded per
test name, so runs are deterministic; there is no shrinking. Install
``hypothesis`` (see requirements-dev.txt) for the real thing.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: random.Random):
        return self._draw(rng)


def _as_strategy(obj) -> Strategy:
    if not isinstance(obj, Strategy):
        raise TypeError(f"expected a strategy, got {obj!r}")
    return obj


# ------------------------------------------------------------- strategies
def integers(min_value=0, max_value=2 ** 31 - 1):
    return Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, allow_nan=False,
           allow_infinity=False, width=64):
    return Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return Strategy(lambda r: r.random() < 0.5)


def sampled_from(seq):
    seq = list(seq)
    return Strategy(lambda r: r.choice(seq))


def lists(elements, min_size=0, max_size=10):
    elements = _as_strategy(elements)

    def draw(r):
        n = r.randint(min_size, max_size)
        return [elements.draw(r) for _ in range(n)]
    return Strategy(draw)


def tuples(*strategies_):
    strategies_ = [_as_strategy(s) for s in strategies_]
    return Strategy(lambda r: tuple(s.draw(r) for s in strategies_))


def text(alphabet="abcdefghij", min_size=0, max_size=10):
    chars = list(alphabet)

    def draw(r):
        n = r.randint(min_size, max_size)
        return "".join(r.choice(chars) for _ in range(n))
    return Strategy(draw)


def dictionaries(keys, values, min_size=0, max_size=10):
    keys, values = _as_strategy(keys), _as_strategy(values)

    def draw(r):
        n = r.randint(min_size, max_size)
        out = {}
        attempts = 0
        while len(out) < n and attempts < 20 * (n + 1):
            out[keys.draw(r)] = values.draw(r)
            attempts += 1
        return out
    return Strategy(draw)


def composite(fn):
    """``fn(draw, *args, **kwargs)`` -> callable returning a Strategy."""
    @functools.wraps(fn)
    def factory(*args, **kwargs):
        return Strategy(lambda r: fn(lambda s: _as_strategy(s).draw(r),
                                     *args, **kwargs))
    return factory


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans,
    sampled_from=sampled_from, lists=lists, tuples=tuples, text=text,
    dictionaries=dictionaries, composite=composite)


# -------------------------------------------------------------- decorators
def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*pos_strategies, **kw_strategies):
    pos_strategies = [_as_strategy(s) for s in pos_strategies]
    kw_strategies = {k: _as_strategy(s) for k, s in kw_strategies.items()}

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # keyword strategies bind by name; positional strategies bind the
        # RIGHTMOST remaining parameters (hypothesis semantics) — anything
        # left over is a pytest fixture and stays in the wrapper signature.
        remaining = [p for p in params if p.name not in kw_strategies]
        n_pos = len(pos_strategies)
        if n_pos:
            drawn_names = [p.name for p in remaining[-n_pos:]]
            fixtures = remaining[:-n_pos]
        else:
            drawn_names = []
            fixtures = remaining

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {name: s.draw(rng)
                         for name, s in zip(drawn_names, pos_strategies)}
                drawn.update({k: s.draw(rng)
                              for k, s in kw_strategies.items()})
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(parameters=fixtures)
        return wrapper
    return deco
