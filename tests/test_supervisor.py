"""supervisord semantics: priority startup order, dependency gating,
restart, status."""
import pytest

from repro.core.services import Replica, Service, ServiceError
from repro.core.supervisor import Supervisor
from repro.serve.clock import VirtualClock


def svc(name, priority, deps=()):
    return Service(name, replicas=[Replica(f"{name}/0", lambda p: p)],
                   priority=priority, depends_on=deps)


def paper_stack():
    """The paper's §4.3 priority layout."""
    sup = Supervisor()
    sup.add(svc("tika", 0))
    sup.add(svc("bert", 1, deps=("tika",)))
    for s in ("personal_information", "education", "work_experience",
              "skills", "functional_area"):
        sup.add(svc(s, 2, deps=("bert",)))
    sup.add(svc("cv_parser", 3, deps=("tika", "bert",
                                      "personal_information", "education",
                                      "work_experience", "skills",
                                      "functional_area")))
    return sup


def test_startup_order_respects_priority():
    sup = paper_stack()
    order = sup.start_all()
    assert order[0] == "tika"
    assert order[1] == "bert"
    assert order[-1] == "cv_parser"
    assert set(order[2:7]) == {"personal_information", "education",
                               "work_experience", "skills",
                               "functional_area"}


def test_dependency_violation_raises():
    sup = Supervisor()
    sup.add(svc("cv_parser", 0, deps=("bert",)))   # bert at HIGHER priority
    sup.add(svc("bert", 1))
    with pytest.raises(ServiceError, match="priority ordering"):
        sup.start_all()


def test_unknown_dependency_raises():
    sup = Supervisor()
    sup.add(svc("a", 0, deps=("ghost",)))
    with pytest.raises(ServiceError, match="unknown dependency"):
        sup.start_all()


def test_restart_and_status():
    sup = paper_stack()
    sup.start_all()
    sup.restart("bert")
    st = sup.status()
    assert st["bert"]["state"] == "RUNNING"
    assert st["cv_parser"]["priority"] == 3
    sup.stop_all()
    assert all(v["state"] == "STOPPED" for v in sup.status().values())


def test_flaky_start_retries():
    attempts = {"n": 0}

    class Flaky(Service):
        def start(self):
            attempts["n"] += 1
            if attempts["n"] < 3:
                raise RuntimeError("boom")
            super().start()

    # restart backoff runs on an injected sleep: the virtual clock
    # records each wait and advances instead of blocking the test
    vc = VirtualClock()
    sup = Supervisor(max_restarts=5, backoff_s=1.0, sleep=vc.sleep)
    sup.add(Flaky("flaky", replicas=[Replica("f/0", lambda p: p)],
                  priority=0))
    sup.start_all()
    assert attempts["n"] == 3
    assert sup.services["flaky"].started
    assert vc.sleeps == [1.0, 2.0]       # linear backoff, zero wall-clock


# ------------------------------------------------- restart accounting
def test_snapshot_counts_restart_attempts():
    attempts = {"n": 0}

    class Flaky(Service):
        def start(self):
            attempts["n"] += 1
            if attempts["n"] < 3:
                raise RuntimeError("boom")
            super().start()

    sup = Supervisor(max_restarts=5)
    sup.add(Flaky("flaky", replicas=[Replica("f/0", lambda p: p)],
                  priority=0))
    sup.add(svc("steady", 1))
    sup.start_all()
    snap = sup.snapshot()
    assert snap["flaky"]["restart_attempts"] == 2      # two failed starts
    assert snap["flaky"]["restarts_exhausted"] is False
    assert snap["flaky"]["max_restarts"] == 5
    assert snap["flaky"]["state"] == "RUNNING"
    assert snap["steady"]["restart_attempts"] == 0
    # snapshot keeps everything status() reports
    assert snap["steady"]["priority"] == 1
    assert "replicas" in snap["steady"]


def test_snapshot_marks_exhausted_restart_budget():
    class Dead(Service):
        def start(self):
            raise RuntimeError("always down")

    sup = Supervisor(max_restarts=2)
    sup.add(Dead("dead", replicas=[Replica("d/0", lambda p: p)],
                 priority=0))
    with pytest.raises(RuntimeError, match="always down"):
        sup.start_all()
    snap = sup.snapshot()
    # max_restarts=2 allows 3 start attempts before giving up
    assert snap["dead"]["restart_attempts"] == 3
    assert snap["dead"]["restarts_exhausted"] is True
    assert snap["dead"]["state"] == "STOPPED"


def test_restart_attempts_accumulate_across_restarts():
    fail_next = {"on": False}

    class Sometimes(Service):
        def start(self):
            if fail_next["on"]:
                fail_next["on"] = False
                raise RuntimeError("hiccup")
            super().start()

    sup = Supervisor(max_restarts=3)
    sup.add(Sometimes("svc", replicas=[Replica("s/0", lambda p: p)],
                      priority=0))
    sup.start_all()
    assert sup.snapshot()["svc"]["restart_attempts"] == 0
    fail_next["on"] = True
    sup.restart("svc")                   # one failure, then recovers
    snap = sup.snapshot()
    assert snap["svc"]["restart_attempts"] == 1
    assert snap["svc"]["state"] == "RUNNING"
    assert snap["svc"]["restarts_exhausted"] is False
