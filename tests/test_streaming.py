"""Async continuous-batching serve loop: deterministic concurrency
harness (virtual clock + scripted arrival traces, zero wall-clock
sleeps).

The load-bearing guarantee: per-token streams produced by the async
dispatch → plan-ahead → commit loop are **bit-identical** to the
synchronous tick drain, across the full engine grid (paged / kernel /
shared-prefix / chunked / speculative). The engine's determinism story
makes this provable rather than flaky: a request's tokens do not depend
on batch composition or admission timing (mixed-length bit-exact decode
+ counter-based sampling), so concurrency changes *when* tokens stream,
never *what* they are.

Everything here is driven, not slept: the harness pumps
``AsyncServeLoop.run_once()`` against scripted traces and advances a
``VirtualClock`` by hand, so a loaded CI host can't turn a live request
into a shed one or hide a lost wakeup behind a generous sleep.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.balancer import deploy
from repro.core.services import (Replica, RequestError, Service,
                                 ServiceError)
from repro.models.model import build_model
from repro.serve.async_loop import AsyncServeLoop
from repro.serve.clock import VirtualClock
from repro.serve.engine import Request, ServingEngine
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import Scheduler
from repro.serve.service import make_lm_service

MAX_SEQ = 64


@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=1):
    rng = jax.random.key(seed)
    out = []
    for L in lens:
        rng, k = jax.random.split(rng)
        out.append(jax.random.randint(k, (L,), 2, cfg.vocab_size).tolist())
    return out


def _build_loop(model, params, *, batch_size=4, vc=None, **kw):
    vc = vc or VirtualClock()
    eng = ServingEngine(model, params, batch_size=batch_size,
                        max_seq=MAX_SEQ, clock=vc, **kw)
    sched = Scheduler(eng, clock=vc)
    return eng, sched, AsyncServeLoop(sched), vc


def _pump(loop, vc, *, until, limit=2000):
    """Drive the loop tick by tick (virtual 10 ms each) until the
    predicate holds."""
    t = 0
    while not until():
        loop.run_once()
        vc.advance(0.01)
        t += 1
        assert t < limit, "serve loop did not converge"
    return t


# --------------------------------------------------- grid bit-identity
# engine kwargs + prompt lengths per config; "SPEC" is resolved to a
# self-draft speculative engine in the test body (needs model/params)
GRID = {
    "paged": ({}, [5, 9, 7, 12, 6]),
    "kernel": ({"use_kernel": True}, [5, 9, 7, 12, 6]),
    "shared_prefix": ({}, None),          # prompts share a long stem
    "chunked": ({"prefill_chunk": 8}, [21, 30, 17, 26, 19]),
    "speculative": ("SPEC", [5, 9, 7, 12, 6]),
}


@pytest.mark.parametrize("config", list(GRID))
def test_async_streams_bit_identical_to_sync_drain(stack, config):
    """Staggered open-loop arrivals through the async loop emit, per
    request, exactly the token/logprob stream a synchronous closed-loop
    drain emits — on every engine config, greedy and sampled."""
    cfg, model, params = stack
    kw, lens = GRID[config]
    if kw == "SPEC":
        kw = {"draft_model": model, "draft_params": params,
              "speculation": 3}
    if config == "shared_prefix":
        stem = _prompts(cfg, [20], seed=7)[0]
        tails = _prompts(cfg, [3, 5, 2, 4], seed=8)
        prompts = [list(stem)] + [stem + tl for tl in tails]
    else:
        prompts = _prompts(cfg, lens, seed=2)

    def mk(base):
        reqs = []
        for i, p in enumerate(prompts):
            samp = SamplingParams(temperature=0.8, top_k=8, seed=3) \
                if i == 1 else SamplingParams()
            reqs.append(Request(rid=base + i, prompt=list(p),
                                max_new_tokens=4, sampling=samp))
        return reqs

    eng, sched, loop, vc = _build_loop(model, params, **kw)
    reqs = mk(0)
    streams = {r.rid: [] for r in reqs}
    handles = {}

    def drive():
        # arrivals staggered 2 ticks apart: request i lands mid-decode
        # of its predecessors, exercising continuous batching
        for i, r in enumerate(reqs):
            if r.rid not in handles and 2 * i <= drive.t:
                handles[r.rid] = loop.submit(
                    r, lambda tok, lp, rid=r.rid:
                        streams[rid].append((tok, lp)))
        drive.t += 1
        return len(handles) == len(reqs) \
            and all(h.done for h in handles.values())
    drive.t = 0
    _pump(loop, vc, until=drive)

    ref = ServingEngine(model, params, batch_size=4, max_seq=MAX_SEQ,
                        **kw)
    ref_done = {r.rid - 100: r for r in ref.run(mk(100))}
    assert len(ref_done) == len(reqs)
    for r in reqs:
        reply = handles[r.rid].reply
        toks = [t for t, _ in streams[r.rid]]
        lps = [lp for _, lp in streams[r.rid]]
        assert toks == reply["tokens"] == ref_done[r.rid].out_tokens, \
            (config, r.rid)
        assert lps == reply["logprobs"], (config, r.rid)
        if config in ("shared_prefix", "chunked"):
            # different arrival patterns change which XLA program computes
            # the prompt-final logits (chunk window vs prefill gather, and
            # what co-batches with it) — tokens stay bit-exact, logprobs
            # to float tolerance (the test_chunked.py contract)
            np.testing.assert_allclose(lps, ref_done[r.rid].out_logprobs,
                                       rtol=2e-5, atol=2e-5)
        else:
            assert lps == ref_done[r.rid].out_logprobs, (config, r.rid)
        assert len(toks) == 4
    if eng.paged:
        eng.pool.check()                   # raises on invariant breach
        assert eng.pool.available == eng.pool.total


def test_tokens_stream_incrementally_not_at_completion(stack):
    """TTFT < completion: tokens surface while the request is still
    decoding, across multiple loop ticks."""
    cfg, model, params = stack
    eng, sched, loop, vc = _build_loop(model, params)
    (p,) = _prompts(cfg, [6], seed=3)
    seen_ticks = []
    tick = [0]
    h = loop.submit(Request(rid=1, prompt=p, max_new_tokens=6),
                    lambda t, lp: seen_ticks.append(tick[0]))

    def drive():
        tick[0] += 1
        return h.done
    _pump(loop, vc, until=drive)
    assert len(seen_ticks) == 6
    assert seen_ticks[0] < seen_ticks[-1]      # not one burst at the end
    assert seen_ticks == sorted(seen_ticks)
    assert h.reply["tokens"] == h.request.out_tokens


def test_cancel_mid_stream_recycles_slot_and_blocks(stack):
    """Cancel frees the slot and its refcounted blocks mid-generation;
    the reply carries the partial stream; co-resident requests are
    untouched and the pool drains clean."""
    cfg, model, params = stack
    eng, sched, loop, vc = _build_loop(model, params, batch_size=2)
    pa, pb, pc = _prompts(cfg, [5, 8, 6], seed=4)
    got_a = []
    ha = loop.submit(Request(rid=1, prompt=pa, max_new_tokens=30),
                     lambda t, lp: got_a.append(t))
    hb = loop.submit(Request(rid=2, prompt=pb, max_new_tokens=4))
    hc = loop.submit(Request(rid=3, prompt=pc, max_new_tokens=4))  # queued
    _pump(loop, vc, until=lambda: len(got_a) >= 3)
    ha.cancel()
    _pump(loop, vc, until=lambda: ha.done)
    assert ha.cancelled
    assert ha.reply["tokens"] == got_a            # partial stream kept
    assert 3 <= len(got_a) < 30
    assert eng.metrics["cancelled"] == 1
    _pump(loop, vc, until=lambda: hb.done and hc.done)
    assert len(hb.reply["tokens"]) == len(hc.reply["tokens"]) == 4
    # the freed slot was actually recycled for the queued request
    assert sched.stats.completed == 2
    eng.pool.check()
    assert eng.pool.available == eng.pool.total


def test_cancel_while_queued_never_occupies_a_slot(stack):
    cfg, model, params = stack
    eng, sched, loop, vc = _build_loop(model, params, batch_size=1)
    pa, pb = _prompts(cfg, [5, 7], seed=5)
    ha = loop.submit(Request(rid=1, prompt=pa, max_new_tokens=6))
    hb = loop.submit(Request(rid=2, prompt=pb, max_new_tokens=2))
    _pump(loop, vc, until=lambda: len(ha.request.out_tokens) >= 1)
    hb.cancel()                                   # still in the queue
    _pump(loop, vc, until=lambda: hb.done)
    assert hb.cancelled and hb.reply["tokens"] == []
    _pump(loop, vc, until=lambda: ha.done)
    assert len(ha.reply["tokens"]) == 6
    assert hb.request.out_tokens == []            # never decoded
    assert eng.pool.available == eng.pool.total


# ------------------------------------------------------- property test
@pytest.fixture(scope="module")
def prop_stack(stack):
    """One engine/loop pair reused across hypothesis examples (each
    ServingEngine owns fresh jitted closures — rebuilding per example
    would recompile)."""
    cfg, model, params = stack
    eng, sched, loop, vc = _build_loop(model, params, batch_size=3)
    return cfg, eng, sched, loop, vc


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["arrive", "cancel",
                                           "disconnect"]),
                          st.integers(min_value=0, max_value=7),
                          st.integers(min_value=0, max_value=3)),
                min_size=3, max_size=12))
def test_random_arrival_cancel_disconnect_traces(prop_stack, trace):
    """Random traces against the loop: token order per request is
    preserved (the streamed list is always a prefix of the engine's
    stream), cancelled/disconnected slots recycle, and the pool's
    refcount invariants hold after every tick, then drain clean."""
    cfg, eng, sched, loop, vc = prop_stack
    prompts = _prompts(cfg, [4, 6, 5, 7, 5, 6, 4, 8], seed=6)
    handles, streams, poisoned = {}, {}, set()
    rid = [0]

    def arrive(_):
        rid[0] += 1
        r = rid[0]
        streams[r] = []

        def tap(tok, lp, r=r):
            if r in poisoned:
                raise ConnectionResetError("client went away")
            streams[r].append(tok)
        handles[r] = loop.submit(
            Request(rid=r, prompt=list(prompts[r % len(prompts)]),
                    max_new_tokens=5), tap)

    def live():
        return [h for h in handles.values() if not h.done]

    def cancel(i):
        alive = live()
        if alive:
            alive[i % len(alive)].cancel()

    def disconnect(i):
        alive = live()
        if alive:
            poisoned.add(alive[i % len(alive)].rid)

    for op, i, gap in trace:
        {"arrive": arrive, "cancel": cancel, "disconnect": disconnect}[op](i)
        for _ in range(gap):
            loop.run_once()
            vc.advance(0.01)
            eng.pool.check()               # allocator invariants hold
            for r, h in handles.items():
                # streamed tokens are always an in-order prefix of the
                # engine's stream for that request
                assert streams[r] == h.request.out_tokens[:len(streams[r])]
    _pump(loop, vc, until=lambda: all(h.done for h in handles.values()))
    for r, h in handles.items():
        if h.cancelled:
            assert h.reply is not None
        elif r in poisoned and h.error is not None:
            assert isinstance(h.error, RequestError)
        else:
            assert h.reply["tokens"] == h.request.out_tokens
    # every slot and block recycled for the next example
    assert eng.active == 0 and eng.waiting == 0
    eng.pool.check()
    assert eng.pool.available == eng.pool.total
    assert not loop._live and not loop._intake and not loop._cancels


# ------------------------------------------------- robustness / service
def test_replica_kill_mid_stream_is_service_error(stack):
    """Supervisor-style kill (set_up(False)) mid-stream: the open stream
    surfaces a retryable ServiceError, fresh requests fail over to the
    healthy replica, and the service stays up."""
    cfg, model, params = stack
    svc = make_lm_service("lm_kill", model, params, n_replicas=2,
                          batch_size=2, max_seq=MAX_SEQ,
                          with_backup=False)
    svc.start()
    rep0 = svc.replicas[0]
    got = []
    handle = rep0.handler.submit({"prompt": [5, 6, 7],
                                  "max_new_tokens": 8,
                                  "on_token": lambda t, lp: got.append(t)})
    loop = rep0.handler.loop
    while len(got) < 2:
        loop.run_once()
    rep0.set_up(False)                    # kill → abort in-flight streams
    with pytest.raises(ServiceError, match="abort"):
        loop.wait(handle)
    assert 2 <= len(got) < 8              # stream stopped mid-flight
    # untouched requests route around the dead replica
    out = svc({"prompt": [5, 6, 7], "max_new_tokens": 2})
    assert out["replica"] == "lm_kill/1"
    assert len(out["tokens"]) == 2


def test_balancer_does_not_retry_after_first_streamed_token():
    """Once a token reached the client, a replica failure must NOT
    replay the request elsewhere (the client would see a duplicated
    prefix) — but it still counts against the replica's health."""
    calls = []

    def flaky(payload):
        calls.append("flaky")
        payload["on_token"](7, -0.5)
        raise ServiceError("died mid-stream")

    def healthy(payload):
        calls.append("healthy")
        return {"tokens": [1]}

    svc = Service("s", replicas=[Replica("a", flaky),
                                 Replica("b", healthy)])
    deploy(svc)
    svc.start()
    got = []
    with pytest.raises(ServiceError, match="not retrying"):
        svc({"on_token": lambda t, lp: got.append(t)})
    assert got == [7]
    assert calls == ["flaky"]             # no replay on the healthy one
    assert svc.balancer.stats["failovers"] == 1   # health still charged
    # a failure BEFORE any token still fails over as always
    assert svc({"on_token": lambda t, lp: None}) == {"tokens": [1]}
    assert calls[-1] == "healthy"


def test_client_disconnect_mid_stream_never_poisons_health(stack):
    """A callback that raises is the CLIENT hanging up: RequestError,
    zero failovers, and the replica keeps serving."""
    cfg, model, params = stack
    svc = make_lm_service("lm_disc", model, params, n_replicas=1,
                          batch_size=2, max_seq=MAX_SEQ)
    svc.start()

    def hangup(tok, lp):
        raise BrokenPipeError("peer reset")

    with pytest.raises(RequestError, match="disconnected"):
        svc({"prompt": [5, 6, 7], "max_new_tokens": 4,
             "on_token": hangup})
    assert svc.balancer.stats["failovers"] == 0
    rep = svc.replicas[0].handler
    assert rep.scheduler.engine.metrics["cancelled"] == 1
    out = svc({"prompt": [5, 6, 7], "max_new_tokens": 2})
    assert len(out["tokens"]) == 2        # slot recycled, replica healthy


def test_streaming_through_service_matches_reply(stack):
    """The on_token payload path through Service → balancer → replica
    delivers exactly the reply's tokens, in order."""
    cfg, model, params = stack
    svc = make_lm_service("lm_stream", model, params, n_replicas=1,
                          batch_size=2, max_seq=MAX_SEQ)
    svc.start()
    got = []
    out = svc({"prompt": [5, 6, 7], "max_new_tokens": 5,
               "on_token": lambda t, lp: got.append((t, lp))})
    assert [t for t, _ in got] == out["tokens"]
    assert [lp for _, lp in got] == out["logprobs"]
    assert len(got) == 5


# ------------------------------------------------------------- asyncio
def test_asyncio_stream_front_end_interleaves(stack):
    """Two concurrent asyncio streams over one loop interleave token
    delivery and both match the engine's streams (runs under
    PYTHONASYNCIODEBUG=1 in CI to catch un-awaited coroutines)."""
    import asyncio

    cfg, model, params = stack
    eng, sched, loop, vc = _build_loop(model, params, batch_size=2)
    pa, pb = _prompts(cfg, [5, 7], seed=9)
    order = []

    async def consume(rid, prompt):
        toks = []
        async for tok, lp in loop.stream(
                Request(rid=rid, prompt=list(prompt), max_new_tokens=4)):
            toks.append(tok)
            order.append(rid)
        return toks

    ta, tb = asyncio.run(_gather_two(consume(1, pa), consume(2, pb)))
    reqs = {1: ta, 2: tb}
    for rid, toks in reqs.items():
        assert len(toks) == 4
    # delivery interleaved rather than one stream fully first
    assert order != sorted(order)


async def _gather_two(a, b):
    import asyncio
    return await asyncio.gather(a, b)


def test_threaded_loop_serves_without_polling_sleeps(stack):
    """The daemon-thread pump is event-woken: submit → wait round-trips
    without the test (or the loop) ever sleeping on a timer."""
    cfg, model, params = stack
    eng, sched, loop, vc = _build_loop(model, params, batch_size=2)
    loop.start()
    try:
        (p,) = _prompts(cfg, [6], seed=10)
        h = loop.submit(Request(rid=1, prompt=p, max_new_tokens=3))
        reply = loop.wait(h)
        assert len(reply["tokens"]) == 3
    finally:
        loop.stop()
    assert eng.pool.available == eng.pool.total


def test_dispatched_tick_commits_exactly_once(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=MAX_SEQ)
    (p,) = _prompts(cfg, [5], seed=11)
    assert eng.add_requests([Request(rid=1, prompt=p,
                                     max_new_tokens=1)]) == 1
    tick = eng.dispatch_step()
    done = tick.commit()
    assert [r.rid for r in done] == [1]
    with pytest.raises(RuntimeError, match="already committed"):
        tick.commit()
