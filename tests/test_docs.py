"""Docs hygiene: every intra-repo markdown link (and #anchor) resolves.
Same check CI's docs job runs via scripts/check_doc_links.py."""
import importlib.util
from pathlib import Path


def test_doc_links_resolve(capsys):
    script = Path(__file__).resolve().parents[1] / "scripts" \
        / "check_doc_links.py"
    spec = importlib.util.spec_from_file_location("check_doc_links", script)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rc = mod.main()
    out = capsys.readouterr().out
    assert rc == 0, f"broken doc links:\n{out}"


def test_front_door_docs_exist():
    repo = Path(__file__).resolve().parents[1]
    for rel in ("README.md", "docs/architecture.md", "docs/paged-kv.md",
                "docs/serving.md"):
        assert (repo / rel).exists(), rel
