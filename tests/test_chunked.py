"""Chunked prefill: decode-interleaved prompt ingestion.

The tentpole property: a prompt split into chunk windows emits token
streams **bit-identical** to monolithic prefill across the whole engine
grid {paged, kernel, shared-prefix, stripe, speculative} — including
chunk sizes that don't divide the prompt, chunk boundaries landing
mid-block, park/preempt between chunks, and chunked admissions churning
against decode. Plus the knobs: the scheduler's per-tick prefill token
budget and the service-level chunk-size payload key.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import Scheduler


@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reqs(cfg, lens, max_new=5, seed=1, **kw):
    rng = jax.random.key(seed)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=max_new,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist(), **kw))
    return out


def _shared_reqs(cfg, n, prefix_len=16, suffix_len=24, max_new=5, seed=5,
                 **kw):
    rng = jax.random.key(seed)
    rng, k = jax.random.split(rng)
    common = jax.random.randint(k, (prefix_len,), 2, cfg.vocab_size).tolist()
    out = []
    for i in range(n):
        rng, k = jax.random.split(rng)
        sfx = jax.random.randint(k, (suffix_len,), 2,
                                 cfg.vocab_size).tolist()
        out.append(Request(rid=i, prompt=common + sfx, max_new_tokens=max_new,
                           **kw))
    return out


def _streams_equal(xs, ys):
    for x, y in zip(xs, ys):
        assert x.out_tokens == y.out_tokens, \
            (x.rid, x.out_tokens, y.out_tokens)


# ============================================== the bit-exactness grid
LENS = [40, 7, 23, 55]


@pytest.mark.parametrize("chunk", [16, 7])   # dividing-ish and not
def test_chunked_matches_monolithic_paged(stack, chunk):
    """Chunk windows (incl. a width that divides neither the prompts nor
    the block size — boundaries land mid-block) reproduce monolithic
    streams through the default paged engine."""
    cfg, model, params = stack
    a, b = _reqs(cfg, LENS, max_new=6), _reqs(cfg, LENS, max_new=6)
    mono = ServingEngine(model, params, batch_size=4, max_seq=64,
                         block_size=16, prefill_chunk=0)
    chunked = ServingEngine(model, params, batch_size=4, max_seq=64,
                            block_size=16, prefill_chunk=chunk)
    mono.run(list(a))
    chunked.run(list(b))
    _streams_equal(a, b)
    assert chunked.metrics["chunked_admissions"] >= 3   # 40, 23, 55 > chunk
    assert chunked.metrics["chunk_steps"] > 0
    assert chunked.pool.available == chunked.pool.total
    chunked.pool.check()
    # logprobs agree to float tolerance (different XLA programs compute
    # the prompt-final logits: prefill's last_idx gather vs the window)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x.out_logprobs, y.out_logprobs,
                                   rtol=2e-5, atol=2e-5)


def test_chunked_matches_monolithic_stripe(stack):
    """Same property through the fixed-stripe layout (paged=False)."""
    cfg, model, params = stack
    a, b = _reqs(cfg, LENS, max_new=6), _reqs(cfg, LENS, max_new=6)
    mono = ServingEngine(model, params, batch_size=4, max_seq=64,
                         paged=False, prefill_chunk=0)
    chunked = ServingEngine(model, params, batch_size=4, max_seq=64,
                            paged=False, prefill_chunk=16)
    mono.run(list(a))
    chunked.run(list(b))
    _streams_equal(a, b)
    assert chunked.metrics["chunk_steps"] > 0


def test_chunked_matches_monolithic_kernel(stack):
    """Chunk windows through the Pallas paged-attention read (interpret
    mode on CPU): ONE fused multi-token kernel launch per chunk tick —
    causal-in-window masking, per-row base lengths — streams unchanged
    versus a monolithic gather-path engine."""
    cfg, model, params = stack
    lens = [21, 9, 30]
    a, b = _reqs(cfg, lens, max_new=4), _reqs(cfg, lens, max_new=4)
    mono = ServingEngine(model, params, batch_size=3, max_seq=64,
                         block_size=8, use_kernel=False, prefill_chunk=0)
    chunked = ServingEngine(model, params, batch_size=3, max_seq=64,
                            block_size=8, use_kernel=True, prefill_chunk=8)
    mono.run(list(a))
    chunked.run(list(b))
    _streams_equal(a, b)


@pytest.mark.parametrize("chunk,block", [(7, 8), (12, 8), (5, 4)])
def test_chunked_kernel_vs_gather_grid(stack, chunk, block):
    """Kernel-vs-gather grid for chunk windows: the fused Pallas window
    kernel and the portable jnp gather path emit identical token
    streams across chunk widths that divide neither the prompts nor
    the block size (boundaries land mid-block), with logprobs agreeing
    to float tolerance and the kernel dispatch counters live."""
    cfg, model, params = stack
    lens = [23, 9, 34]
    a, b = _reqs(cfg, lens, max_new=4), _reqs(cfg, lens, max_new=4)
    gather = ServingEngine(model, params, batch_size=3, max_seq=64,
                           block_size=block, use_kernel=False,
                           prefill_chunk=chunk)
    kern = ServingEngine(model, params, batch_size=3, max_seq=64,
                         block_size=block, use_kernel=True,
                         prefill_chunk=chunk)
    gather.run(list(a))
    kern.run(list(b))
    _streams_equal(a, b)
    assert kern.metrics["chunk_steps"] > 0
    assert kern.metrics["kernel_windows"] > 0
    assert kern.metrics["kernel_positions"] >= kern.metrics["kernel_windows"]
    assert gather.metrics["kernel_windows"] == 0
    for x, y in zip(a, b):
        np.testing.assert_allclose(x.out_logprobs, y.out_logprobs,
                                   rtol=2e-5, atol=2e-5)


def test_park_resume_between_chunks_kernel_vs_gather(stack):
    """Park/resume between chunks on the kernel path: decode growth
    steals the headroom mid-prompt, parking or preempting the
    half-prefilled slot; the resumed windows flow through the fused
    kernel and every stream equals the gather engine's."""
    cfg, model, params = stack

    def run(use_kernel):
        (short,) = _reqs(cfg, [6], max_new=24, seed=11)
        (lng,) = _reqs(cfg, [36], max_new=6, seed=12)
        eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                            block_size=4, num_blocks=13, prefill_chunk=8,
                            use_kernel=use_kernel)
        assert eng.add_requests([short]) == 1
        eng.step()
        assert eng.add_requests([lng]) == 1
        done = eng.run([])
        assert len(done) == 2
        # 12 allocatable blocks cannot hold both at full length:
        # contention between chunks actually happened
        assert eng.metrics["parked_slot_steps"] > 0 \
            or eng.metrics["preemptions"] > 0
        return eng, short, lng

    _, gs, gl = run(False)
    keng, ks_, kl = run(True)
    assert keng.metrics["kernel_windows"] > 0
    _streams_equal([gs, gl], [ks_, kl])


def test_chunked_matches_monolithic_speculative(stack):
    """Speculative engines chunk too: chunk ticks suspend the draft
    window (speculation resumes when the prompts drain) and greedy
    streams stay identical to a non-speculative monolithic engine."""
    cfg, model, params = stack
    a = _shared_reqs(cfg, 3, suffix_len=30, max_new=8, seed=9)
    b = _shared_reqs(cfg, 3, suffix_len=30, max_new=8, seed=9)
    spec = ServingEngine(model, params, batch_size=3, max_seq=96,
                         block_size=8, draft_model=model,
                         draft_params=params, speculation=3,
                         prefill_chunk=8)
    mono = ServingEngine(model, params, batch_size=3, max_seq=96,
                         block_size=8, prefill_chunk=0)
    spec.run(list(a))
    mono.run(list(b))
    _streams_equal(a, b)
    assert spec.metrics["chunk_steps"] > 0     # chunks actually happened
    assert spec.metrics["verify_steps"] > 0    # and speculation resumed


def test_chunked_decode_riders_unperturbed(stack):
    """THE interleaving regression: slots already decoding when a long
    prompt chunk-ingests alongside them keep emitting their exact solo
    streams (their single token rides the chunk window batch)."""
    cfg, model, params = stack
    riders = _reqs(cfg, [6, 11], max_new=12, seed=3)
    solo_copies = _reqs(cfg, [6, 11], max_new=12, seed=3)
    (long_req,) = _reqs(cfg, [48], max_new=3, seed=4)
    (long_solo,) = _reqs(cfg, [48], max_new=3, seed=4)
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        block_size=8, prefill_chunk=8)
    assert eng.add_requests(list(riders)) == 2
    eng.step()                                  # riders mid-decode
    assert eng.add_requests([long_req]) == 1    # first chunk only
    assert eng.slot_pending[2]                  # still owes prompt
    done = eng.run([])
    assert len(done) == 3
    for r, s in zip(riders + [long_req], solo_copies + [long_solo]):
        solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                             block_size=8, prefill_chunk=0)
        solo.run([s])
        assert r.out_tokens == s.out_tokens, r.rid


# ============================================ sharing: the gate is gone
def test_long_unshared_suffix_now_shares_and_chunks(stack):
    """The bounded-suffix trade is dead: a short shared preamble in
    front of a long document engages sharing — the un-shared suffix
    chunk-prefills instead of feeding one token per step."""
    cfg, model, params = stack
    a = _shared_reqs(cfg, 3, prefix_len=16, suffix_len=30, seed=5)
    b = _shared_reqs(cfg, 3, prefix_len=16, suffix_len=30, seed=5)
    on = ServingEngine(model, params, batch_size=3, max_seq=64,
                       block_size=8, prefix_sharing=True, prefill_chunk=8)
    off = ServingEngine(model, params, batch_size=3, max_seq=64,
                        block_size=8, prefix_sharing=False, prefill_chunk=0)
    on.run(list(a))
    off.run(list(b))
    _streams_equal(a, b)
    assert on.metrics["shared_admissions"] == 2
    assert on.metrics["prefill_tokens_shared"] >= 16
    # the suffix drained through chunk windows, not serial catch-up:
    # 30-token suffixes at chunk 8 — far fewer steps than tokens
    assert on.metrics["chunk_prefill_tokens"] > 0
    assert on.metrics["decode_steps"] < off.metrics["decode_steps"] + 30
    on.pool.check()


def test_chunk_written_blocks_register_for_sharing(stack):
    """A chunk-ingested prompt advertises its blocks in the prefix index
    exactly like a monolithic prefill: a later identical prompt shares
    the WHOLE resident prompt, not just the first chunk."""
    cfg, model, params = stack
    rng = jax.random.key(31)
    prompt = jax.random.randint(rng, (42,), 2, cfg.vocab_size).tolist()
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        block_size=8, prefix_sharing=True, prefill_chunk=8)
    first = Request(rid=0, prompt=list(prompt), max_new_tokens=30)
    assert eng.add_requests([first]) == 1
    while eng.slot_pending[0]:                  # drain the chunks
        eng.step()
    second = Request(rid=1, prompt=list(prompt), max_new_tokens=2)
    assert eng.add_requests([second]) == 1
    assert eng.metrics["shared_admissions"] == 1
    # the match covered the whole resident prompt (capped at P-1): far
    # more than the 8-token first chunk
    assert eng.metrics["prefill_tokens_shared"] >= 40
    eng.pool.check()
    done = eng.run([])
    assert len(done) == 2
    solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                         block_size=8, prefix_sharing=False, prefill_chunk=0)
    for r in (first, second):
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens)])
        assert d.out_tokens == r.out_tokens, r.rid


# =================================== contention: park/preempt mid-chunk
def test_park_preempt_between_chunks_resumes_bit_exact(stack):
    """A chunked admission charges its whole prompt at the gate but only
    allocates chunk by chunk — a neighbor's decode growth can steal the
    headroom mid-prompt, parking or preempting the half-prefilled slot.
    Either way every stream must equal its uncontended solo run."""
    cfg, model, params = stack
    (short,) = _reqs(cfg, [6], max_new=24, seed=11)
    (short2,) = _reqs(cfg, [6], max_new=24, seed=11)
    (lng,) = _reqs(cfg, [36], max_new=6, seed=12)
    (lng2,) = _reqs(cfg, [36], max_new=6, seed=12)
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        block_size=4, num_blocks=13, prefill_chunk=8)
    assert eng.add_requests([short]) == 1
    eng.step()
    assert eng.add_requests([lng]) == 1         # 9 blocks charged, 2 held
    done = eng.run([])
    assert len(done) == 2
    # the pool (12 blocks) cannot hold both at full length (8 + 11):
    # contention mid-chunk actually happened
    assert eng.metrics["parked_slot_steps"] > 0 \
        or eng.metrics["preemptions"] > 0
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    for r, s in ((short, short2), (lng, lng2)):
        solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                             block_size=4, prefill_chunk=0)
        solo.run([s])
        assert r.out_tokens == s.out_tokens, r.rid


def test_chunk_degrades_under_pool_pressure(stack):
    """When the pool can only grant part of a chunk window, the slot
    feeds fewer tokens that step instead of stalling — and still
    finishes bit-exact."""
    cfg, model, params = stack
    (a,) = _reqs(cfg, [30], max_new=4, seed=13)
    (b,) = _reqs(cfg, [30], max_new=4, seed=13)
    # 9 allocatable blocks of 4 = 36 tokens: the 16-token chunk windows
    # can't always be granted whole next to the resident prefix
    eng = ServingEngine(model, params, batch_size=1, max_seq=64,
                        block_size=4, num_blocks=10, prefill_chunk=16)
    eng.run([a])
    solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                         block_size=4, prefill_chunk=0)
    solo.run([b])
    assert a.out_tokens == b.out_tokens
    assert eng.pool.available == eng.pool.total


# ======================================================= property churn
@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(4, 40), st.integers(1, 6)),
                min_size=2, max_size=6),
       st.sampled_from([5, 8, 16]),
       st.integers(0, 4))
def test_chunked_churn_property(stack, jobs, chunk, share_prefix_len):
    """Interleaved chunked admissions + decode + retirement churn (with
    optional shared prefixes) keeps pool invariants and emits exactly
    the monolithic engine's streams."""
    cfg, model, params = stack
    rng = jax.random.key(sum(L * 7 + n for L, n in jobs) + chunk)
    rng, k = jax.random.split(rng)
    common = jax.random.randint(k, (share_prefix_len,), 2,
                                cfg.vocab_size).tolist()
    reqs_a, reqs_b = [], []
    for i, (L, new) in enumerate(jobs):
        rng, k = jax.random.split(rng)
        p = common + jax.random.randint(k, (L,), 2, cfg.vocab_size).tolist()
        reqs_a.append(Request(rid=i, prompt=list(p), max_new_tokens=new))
        reqs_b.append(Request(rid=i, prompt=list(p), max_new_tokens=new))
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        block_size=8, num_blocks=20, prefill_chunk=chunk)
    mono = ServingEngine(model, params, batch_size=3, max_seq=64,
                         block_size=8, num_blocks=20, prefill_chunk=0,
                         prefix_sharing=False)
    pending = list(reqs_a)
    while pending or eng.active or eng.waiting or eng._finished_at_admit:
        n = eng.add_requests(pending)
        del pending[:n]
        eng.step()
        eng.pool.check()
    mono.run(list(reqs_b))
    _streams_equal(reqs_a, reqs_b)
    assert eng.pool.available == eng.pool.total


def test_chunk_registration_survives_misaligned_first_chunk(stack):
    """Regression: a first chunk that is NOT a block multiple must keep
    the registration chain open — the partially-filled block registers
    once the chunk steps fill it, so a later identical prompt still
    shares the whole resident prompt (not just the aligned part of the
    first chunk)."""
    cfg, model, params = stack
    rng = jax.random.key(43)
    prompt = jax.random.randint(rng, (42,), 2, cfg.vocab_size).tolist()
    for chunk in (12, 5):           # mid-block, and sub-block (< bs)
        eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                            block_size=8, prefix_sharing=True,
                            prefill_chunk=chunk)
        first = Request(rid=0, prompt=list(prompt), max_new_tokens=30)
        assert eng.add_requests([first]) == 1
        while eng.slot_pending[0]:
            eng.step()
        second = Request(rid=1, prompt=list(prompt), max_new_tokens=1)
        assert eng.add_requests([second]) == 1
        assert eng.metrics["shared_admissions"] == 1, chunk
        assert eng.metrics["prefill_tokens_shared"] >= 40, chunk
        eng.pool.check()


def test_per_request_zero_chunk_is_monolithic(stack):
    """An explicit per-request prefill_chunk=0 opts OUT of chunking
    (matching the engine knob's meaning), and a negative value is a
    loud error — not silent garbage admission."""
    cfg, model, params = stack
    (a,) = _reqs(cfg, [40], max_new=2, seed=27)
    a.prefill_chunk = 0
    eng = ServingEngine(model, params, batch_size=1, max_seq=64,
                        block_size=8, prefill_chunk=8)   # engine chunks
    eng.run([a])
    assert eng.metrics["chunked_admissions"] == 0
    assert eng.metrics["chunk_steps"] == 0
    (b,) = _reqs(cfg, [10], max_new=2, seed=27)
    b.prefill_chunk = -4
    with pytest.raises(ValueError, match="prefill_chunk"):
        eng.add_requests([b])


def test_in_batch_sharing_with_sub_block_first_chunk(stack):
    """The planner hazard: a chunked source admission with a first chunk
    SMALLER than a block registers nothing at admission time, so a
    same-batch peer must not be promised its chains — on a tight pool
    the peer's broken-promise fallback would allocate blocks the
    planner never budgeted. Both requests must serve, bit-exact."""
    cfg, model, params = stack
    rng = jax.random.key(37)
    prompt = jax.random.randint(rng, (30,), 2, cfg.vocab_size).tolist()
    a = Request(rid=0, prompt=list(prompt), max_new_tokens=3)
    b = Request(rid=1, prompt=list(prompt), max_new_tokens=3)
    # chunk 5 < block_size 8: the first chunk registers zero full blocks
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        block_size=8, num_blocks=11, prefill_chunk=5)
    done = eng.run([a, b])
    assert len(done) == 2
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                         block_size=8, prefill_chunk=0)
    for r in (a, b):
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=3)])
        assert d.out_tokens == r.out_tokens, r.rid


# ================================================= draft chunked catch-up
def test_draft_chunked_ingest_matches_in_sync_draft(stack):
    """A draft that fell several tokens behind (the target ran chunk
    ticks without it) catches up in ONE ingest call and then proposes
    exactly what an always-in-sync draft proposes — and the round costs
    1 + k draft steps, not catch - 1 + k."""
    from repro.serve.spec import DraftRunner
    cfg, model, params = stack
    rng = jax.random.key(41)
    ctx = jax.random.randint(rng, (19,), 2, cfg.vocab_size).tolist()
    k = 3
    greedy = (jnp.zeros(1), jnp.zeros(1, jnp.int32),
              jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32))

    lagged = DraftRunner(model, params, batch_size=1, max_seq=64)
    lagged.admit([(0, ctx[:12])])               # cached 12, owes 7
    steps0 = lagged.steps_run
    prop_a, _ = lagged.propose([ctx[12:]], [0], k, *greedy)
    assert lagged.steps_run - steps0 == 1 + k   # one ingest + k proposals

    synced = DraftRunner(model, params, batch_size=1, max_seq=64)
    synced.admit([(0, ctx[:-1])])               # cached all but the last
    prop_b, _ = synced.propose([ctx[-1:]], [0], k, *greedy)
    assert prop_a.tolist() == prop_b.tolist()
    assert int(lagged.len[0]) == int(synced.len[0]) == len(ctx) - 1


# ======================================================= scheduler budget
def test_scheduler_prefill_budget_paces_admissions(stack):
    """With a per-tick prefill token budget, a burst of long prompts
    admits across ticks: continuing chunks are charged first and new
    admissions wait for a tick with room."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=4, max_seq=64,
                        block_size=8, prefill_chunk=16, prefill_budget=16)
    sched = Scheduler(eng, prefill_budget=16)
    reqs = _reqs(cfg, [40, 40, 40], max_new=2, seed=17)
    for r in reqs:
        assert sched.submit(r)
    sched.tick()
    assert eng.active == 1          # 16-token budget: one first chunk
    done = sched.drain()
    assert len(done) == 3
    # cross-check streams against an unbudgeted engine
    unb = ServingEngine(model, params, batch_size=4, max_seq=64,
                        block_size=8, prefill_chunk=0)
    b = _reqs(cfg, [40, 40, 40], max_new=2, seed=17)
    unb.run(list(b))
    _streams_equal(reqs, b)


def test_engine_budget_caps_chunk_tokens_per_step(stack):
    """The engine-side budget bounds pending tokens fed per step across
    slots (every slot still progresses >= 1 token)."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        block_size=8, prefill_chunk=16, prefill_budget=8)
    reqs = _reqs(cfg, [40, 40], max_new=2, seed=19)
    assert eng.add_requests(list(reqs)) == 2    # first chunks: 16 each
    before = eng.metrics["chunk_prefill_tokens"]
    eng.step()
    fed = eng.metrics["chunk_prefill_tokens"] - before
    # budget 8, + the >= 1-token progress guarantee for the second slot
    assert 0 < fed <= 8 + 1
    done = eng.run([])
    assert len(done) == 2


def test_budget_validation():
    with pytest.raises(ValueError, match="prefill_budget"):
        Scheduler(object.__new__(ServingEngine), prefill_budget=0)


# ============================================================ knob edges
def test_engine_rejects_bad_chunk_knobs(stack):
    cfg, model, params = stack
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(model, params, batch_size=1, max_seq=32,
                      prefill_chunk=-1)
    with pytest.raises(ValueError, match="prefill_budget"):
        ServingEngine(model, params, batch_size=1, max_seq=32,
                      prefill_budget=0)


def test_recurrent_and_moe_never_chunk():
    """Families that cannot run multi-token windows stay monolithic —
    and explicitly asking them to chunk is a loud error."""
    rcfg = dataclasses.replace(get_config("rwkv6-1.6b").reduced(),
                               dtype=jnp.float32)
    rmodel = build_model(rcfg)
    rparams = rmodel.init(jax.random.key(0))
    eng = ServingEngine(rmodel, rparams, batch_size=1, max_seq=32)
    assert eng.prefill_chunk == 0
    with pytest.raises(ValueError, match="chunked prefill"):
        ServingEngine(rmodel, rparams, batch_size=1, max_seq=32,
                      prefill_chunk=8)
    mcfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                               dtype=jnp.float32)
    mmodel = build_model(mcfg)
    mparams = mmodel.init(jax.random.key(0))
    meng = ServingEngine(mmodel, mparams, batch_size=1, max_seq=32)
    assert meng.prefill_chunk == 0


def test_per_request_chunk_override(stack):
    """A request's prefill_chunk overrides the engine default for its
    own ingestion; streams stay identical either way."""
    cfg, model, params = stack
    (a,) = _reqs(cfg, [40], max_new=4, seed=23)
    (b,) = _reqs(cfg, [40], max_new=4, seed=23)
    b.prefill_chunk = 8
    eng = ServingEngine(model, params, batch_size=1, max_seq=64,
                        block_size=8, prefill_chunk=0)   # engine monolithic
    eng2 = ServingEngine(model, params, batch_size=1, max_seq=64,
                         block_size=8, prefill_chunk=0)
    eng.run([a])
    eng2.run([b])
    assert a.out_tokens == b.out_tokens
    assert eng2.metrics["chunked_admissions"] == 1
    assert eng.metrics["chunked_admissions"] == 0


def test_service_rejects_bad_prefill_chunk_payload(stack):
    """A non-positive / non-int \"prefill_chunk\" is the CLIENT's fault:
    RequestError, never a replica failure the balancer retries."""
    from repro.core.services import RequestError
    from repro.serve.service import make_lm_service
    cfg, model, params = stack
    svc = make_lm_service("lm-chunk", model, params, n_replicas=1,
                          batch_size=1, max_seq=64, prefill_chunk=8)
    svc.start()
    rep = svc.replicas[0].handler
    for bad in (0, -3, True, "16"):
        with pytest.raises(RequestError, match="prefill_chunk"):
            rep({"prompt": [5, 6, 7], "prefill_chunk": bad})
    out = rep({"prompt": [5, 6, 7] * 8, "max_new_tokens": 3,
               "prefill_chunk": 8})
    assert len(out["tokens"]) == 3
