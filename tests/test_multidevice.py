"""Multi-device behaviours (subprocess with 8 forced host devices):
shard_map EP-MoE == single-device MoE, sequence-sharded decode ==
unsharded decode, and mesh space-sharing parallel == sequential."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_in_subprocess(body: str) -> dict:
    """Run `body` with 8 host devices; it must print a JSON dict."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_ep_moe_matches_single_device():
    res = run_in_subprocess("""
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models import moe
        from repro.sharding.rules import ParallelPlan
        import dataclasses

        cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                                  capacity_factor=8.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = ParallelPlan.make(mesh, cfg, "train")
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        local, _ = moe.moe_ffn_local(
            x.reshape(-1, cfg.d_model), p, cfg)
        local = local.reshape(x.shape)
        dist, _ = jax.jit(lambda x, p: moe.moe_ffn(x, p, cfg, plan))(x, p)
        err = float(jnp.max(jnp.abs(dist - local)))
        print(json.dumps({"err": err, "mode": plan.moe_mode}))
    """)
    assert res["err"] < 2e-4, res


@pytest.mark.slow
def test_ep_moe_kimi_mode_matches():
    res = run_in_subprocess("""
        from repro.configs.base import get_config
        from repro.models import moe
        from repro.sharding.rules import ParallelPlan
        import dataclasses

        cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                                  capacity_factor=8.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = ParallelPlan.make(mesh, cfg, "train")
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        local, _ = moe.moe_ffn_local(x.reshape(-1, cfg.d_model), p, cfg)
        dist, _ = jax.jit(lambda x, p: moe.moe_ffn(x, p, cfg, plan))(x, p)
        err = float(jnp.max(jnp.abs(dist - local.reshape(x.shape))))
        print(json.dumps({"err": err, "mode": plan.moe_mode}))
    """)
    assert res["mode"] == "ep"
    assert res["err"] < 2e-4, res


@pytest.mark.slow
def test_sequence_sharded_decode_matches_unsharded():
    res = run_in_subprocess("""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config
        from repro.models.model import build_model
        from repro.sharding.rules import ParallelPlan

        cfg = get_config("qwen3-4b").reduced()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cache = m.init_cache(2, 64)
        tok = jnp.ones((2, 1), jnp.int32)
        ref, _ = jax.jit(m.decode_step)(params, tok, cache, jnp.int32(32))

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = ParallelPlan.make(mesh, cfg, "decode")
        c_sh = jax.tree_util.tree_map_with_path(
            lambda path, x: jax.device_put(
                x, NamedSharding(mesh, plan.cache_spec(("cache",) + tuple(
                    str(getattr(k, "key", k)) for k in path), x.shape))),
            cache)
        out, _ = jax.jit(lambda p, t, c, l: m.decode_step(p, t, c, l, plan)
                         )(params, tok, c_sh, jnp.int32(32))
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        print(json.dumps({"err": err}))
    """)
    assert res["err"] < 2e-4, res


@pytest.mark.slow
def test_multimodel_space_sharing_parallel_equals_sequential():
    res = run_in_subprocess("""
        from repro.core.multimodel import ModelService, MultiModelServer

        def mk(i):
            w = jnp.eye(16) * (i + 1)
            return ModelService(f"m{i}", lambda p, b: b @ p, w)

        server = MultiModelServer([mk(i) for i in range(4)])
        groups = {s.name: [str(d) for d in s.submesh.devices.ravel()]
                  for s in server.services.values()}
        disjoint = len({d for g in groups.values() for d in g}) == \
            sum(len(g) for g in groups.values())
        batches = {f"m{i}": jnp.ones((4, 16)) for i in range(4)}
        par, t_par = server.serve_parallel(batches)
        seq, t_seq = server.serve_sequential(batches)
        same = all(bool(jnp.allclose(par[k], seq[k])) for k in par)
        print(json.dumps({"disjoint": disjoint, "same": same}))
    """)
    assert res["disjoint"] and res["same"]


@pytest.mark.slow
def test_weight_stationary_moe_decode_matches_local():
    """moe_decode_ffn (token-gather, weight-stationary; §Perf kimi-k2)
    must agree with the single-device oracle under 2-D sharded weights."""
    res = run_in_subprocess("""
        import dataclasses
        from repro.configs.base import get_config
        from repro.models import moe
        from repro.sharding.rules import ParallelPlan

        cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                                  capacity_factor=8.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = ParallelPlan.make(mesh, cfg, "decode")
        plan = dataclasses.replace(plan, weight_fsdp=("data",))
        assert plan.kind == "decode"
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model))
        local, _ = moe.moe_ffn_local(x.reshape(-1, cfg.d_model), p, cfg)
        local = local.reshape(x.shape)
        dist, _ = jax.jit(lambda x, p: moe.moe_ffn(x, p, cfg, plan))(x, p)
        err = float(jnp.max(jnp.abs(dist - local)))
        print(json.dumps({"err": err, "mode": plan.moe_mode}))
    """)
    assert res["err"] < 2e-4, res


@pytest.mark.slow
def test_weight_stationary_moe_decode_ep_matches_local():
    """Same check in EP mode (experts divide the model axis)."""
    res = run_in_subprocess("""
        import dataclasses
        from repro.configs.base import get_config
        from repro.models import moe
        from repro.sharding.rules import ParallelPlan

        cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                                  capacity_factor=8.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        plan = ParallelPlan.make(mesh, cfg, "decode")
        plan = dataclasses.replace(plan, weight_fsdp=("data",))
        assert plan.moe_mode == "ep", plan.moe_mode
        p = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model))
        local, _ = moe.moe_ffn_local(x.reshape(-1, cfg.d_model), p, cfg)
        local = local.reshape(x.shape)
        dist, _ = jax.jit(lambda x, p: moe.moe_ffn(x, p, cfg, plan))(x, p)
        err = float(jnp.max(jnp.abs(dist - local)))
        print(json.dumps({"err": err, "mode": plan.moe_mode}))
    """)
    assert res["err"] < 2e-4, res
