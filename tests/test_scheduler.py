"""Scheduler: admission, continuous batching, SPF vs FIFO, bounded queue,
priority tiers, deadline (EDF) shedding, queue-wait stats.

Deadline/SLO tests run on a VirtualClock (engine + scheduler share it):
expiry is decided by explicit ``advance`` calls, never by how fast the
CI host happens to run — tier-1 stays sleep-free and deterministic."""
import dataclasses

import jax
import pytest

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.clock import VirtualClock
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import Scheduler


@pytest.fixture(scope="module")
def engine_factory():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jax.numpy.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def make(batch=2, max_seq=64, **kw):
        return ServingEngine(model, params, batch_size=batch,
                             max_seq=max_seq, **kw), cfg
    return make


def _reqs(cfg, lens, max_new=3):
    rng = jax.random.key(1)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=max_new,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist()))
    return out


def test_drain_completes_all(engine_factory):
    eng, cfg = engine_factory()
    s = Scheduler(eng)
    for r in _reqs(cfg, [8, 12, 8, 10, 6]):
        assert s.submit(r)
    done = s.drain()
    assert len(done) == 5
    assert s.stats.completed == 5
    assert all(len(r.out_tokens) == 3 for r in done)
    assert s.stats.queue_peak >= 3          # engine batch=2, 5 submitted


def test_bounded_queue_rejects(engine_factory):
    eng, cfg = engine_factory()
    s = Scheduler(eng, max_queue=2)
    reqs = _reqs(cfg, [8] * 4)
    assert s.submit(reqs[0]) and s.submit(reqs[1])
    assert not s.submit(reqs[2])
    assert s.stats.rejected == 1
    s.drain()
    assert s.stats.completed == 2


def test_spf_prefers_short_prompts(engine_factory):
    eng, cfg = engine_factory(batch=1)
    s = Scheduler(eng, policy="spf")
    reqs = _reqs(cfg, [32, 4, 16], max_new=2)
    for r in reqs:
        s.submit(r)
    order = []
    while s.queue or any(r is not None for r in eng.slot_req):
        for r in s.tick():
            order.append(r.rid)
    assert order[0] == 1                    # shortest (len 4) served first
    assert s.stats.completed == 3


def test_spf_beats_fifo_on_head_of_line_blocking(engine_factory):
    """With one slot and a long prompt at the head, SPF completes the
    short requests in strictly fewer ticks than they'd wait under FIFO."""
    eng, cfg = engine_factory(batch=1)
    s = Scheduler(eng, policy="spf")
    reqs = _reqs(cfg, [48, 4, 4, 4], max_new=2)
    for r in reqs:
        s.submit(r)
    done = s.drain()
    # the long rid-0 prompt finishes LAST under SPF
    assert [r.rid for r in done][-1] == 0
    # and every short request waited fewer ticks than the long one ran
    assert s.stats.completed == 4


def test_queue_wait_stats_recorded(engine_factory):
    eng, cfg = engine_factory(batch=2)
    s = Scheduler(eng)
    for r in _reqs(cfg, [8] * 5):
        s.submit(r)
    s.drain()
    assert len(s.stats.queue_wait_s) == 5
    assert all(w >= 0 for w in s.stats.queue_wait_s)
    assert s.stats.mean_queue_wait_s() >= 0
    # requests 3 and 4 queued behind a full engine: they waited longer
    # than the first pair, which was admitted on the first tick
    first_two = sorted(s.stats.queue_wait_s)[:2]
    last_two = sorted(s.stats.queue_wait_s)[-2:]
    assert max(first_two) <= min(last_two)


def test_bounded_queue_rejection_counting(engine_factory):
    eng, cfg = engine_factory(batch=1)
    s = Scheduler(eng, max_queue=3)
    reqs = _reqs(cfg, [8] * 6, max_new=2)
    outcomes = [s.submit(r) for r in reqs]
    assert outcomes == [True] * 3 + [False] * 3
    assert s.stats.rejected == 3
    s.drain()
    assert s.stats.completed == 3


def test_oversized_prompt_rejected_at_submit(engine_factory):
    """Prompt > max_seq can never be served: reject up front instead of
    blowing up a co-dequeued batch inside tick()."""
    eng, cfg = engine_factory(batch=2, max_seq=16)
    s = Scheduler(eng)
    ok, big = _reqs(cfg, [8], max_new=2)[0], Request(
        rid=99, prompt=[3] * 50, max_new_tokens=2)
    assert not s.submit(big)
    assert s.stats.rejected == 1
    assert s.submit(ok)
    done = s.drain()
    assert [r.rid for r in done] == [ok.rid]   # batchmate unharmed


# ------------------------------------------------------------- priority
def test_priority_tiers_served_first(engine_factory):
    eng, cfg = engine_factory(batch=1)
    s = Scheduler(eng, policy="priority")
    reqs = _reqs(cfg, [8, 8, 8, 8], max_new=2)
    reqs[2].priority = 5                     # late submitter, high tier
    reqs[3].priority = 5
    for r in reqs:
        s.submit(r)
    done = s.drain()
    assert [r.rid for r in done] == [2, 3, 0, 1]   # tier first, FIFO inside
    assert s.stats.completed_by_priority == {5: 2, 0: 2}


# ------------------------------------------------------------- deadline
def test_deadline_policy_serves_edf_order(engine_factory):
    eng, cfg = engine_factory(batch=1)
    eng.clock = vc = VirtualClock(start=1000.0)
    s = Scheduler(eng, policy="deadline")    # shares the engine's clock
    reqs = _reqs(cfg, [8, 8, 8], max_new=2)
    reqs[0].deadline_s = vc.now() + 500.0
    reqs[1].deadline_s = vc.now() + 100.0    # tightest -> first
    reqs[2].deadline_s = None                # no SLO -> last
    for r in reqs:
        s.submit(r)
    done = s.drain()
    assert [r.rid for r in done] == [1, 0, 2]
    assert s.stats.slo_hits == 2             # virtual time never advanced
    assert s.stats.slo_misses == 0


def test_deadline_sheds_expired_requests(engine_factory):
    eng, cfg = engine_factory(batch=1)
    eng.clock = vc = VirtualClock(start=1000.0)
    s = Scheduler(eng, policy="deadline")
    live, doomed = _reqs(cfg, [8, 8], max_new=2)
    live.deadline_s = vc.now() + 500.0
    s.submit(live)
    s.submit(doomed)
    doomed.deadline_s = vc.now() + 1.0
    vc.advance(2.0)                          # expires in the queue
    done = s.drain()
    assert [r.rid for r in done] == [live.rid]
    assert s.stats.shed == 1
    assert s.shed_requests == [doomed]
    assert s.stats.completed == 1


def test_deadline_rejects_expired_at_submit(engine_factory):
    eng, cfg = engine_factory(batch=1)
    eng.clock = vc = VirtualClock(start=1000.0)
    s = Scheduler(eng, policy="deadline")
    (dead,) = _reqs(cfg, [8], max_new=2)
    dead.deadline_s = vc.now() - 1.0
    assert not s.submit(dead)
    assert s.stats.rejected == 1
    assert not s.queue


# ------------------------------------------------- paged pool integration
@pytest.fixture(scope="module")
def paged_factory():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jax.numpy.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def make(batch=4, max_seq=64, block_size=8, num_blocks=None):
        return ServingEngine(model, params, batch_size=batch,
                             max_seq=max_seq, paged=True,
                             block_size=block_size,
                             num_blocks=num_blocks), cfg
    return make


def test_fill_is_gated_on_pool_blocks(paged_factory):
    """Free slots alone no longer admit: with a near-empty pool the fill
    loop stops at the first request the pool cannot hold, and the
    blocked head is served once blocks free up."""
    eng, cfg = paged_factory(batch=8, num_blocks=5)   # 4 blocks, 8 slots
    s = Scheduler(eng)
    for r in _reqs(cfg, [5, 5, 5, 5, 5], max_new=3):
        assert s.submit(r)
    s.tick()
    assert eng.active == 3                   # 3 x 1 block + 1 reserve
    assert len(s.queue) == 2                 # head waits, order preserved
    done = s.drain()
    assert s.stats.completed == 5
    assert [r.rid for r in done][-2:] == [3, 4]


def test_unservable_prompt_rejected_at_submit_paged(paged_factory):
    """A prompt needing more blocks than the whole pool can never run."""
    eng, cfg = paged_factory(batch=2, max_seq=64, num_blocks=3)  # 2 blocks
    s = Scheduler(eng)
    (big,) = _reqs(cfg, [40], max_new=2)     # 5 blocks of 8 > pool 2
    assert not s.submit(big)
    assert s.stats.rejected == 1


def test_memory_pressure_sheds_lowest_priority(paged_factory):
    """Shedding fires on MEMORY pressure: slots are free, blocks are
    not — the backlog is trimmed lowest-priority-first to what the pool
    can still hold."""
    eng, cfg = paged_factory(batch=8, num_blocks=5)   # 4 blocks
    s = Scheduler(eng, policy="priority", pressure_shed=0.5)
    reqs = _reqs(cfg, [5] * 6, max_new=3)
    reqs[4].priority = 7
    reqs[5].priority = 3
    for r in reqs:
        assert s.submit(r)
    done = s.tick()                          # admits 3 (1 block each + spare)
    assert eng.memory_pressure() >= 0.5
    done += s.tick()                         # pressure >= threshold: shed
    # priority picks admitted rid4 (pri 7), rid5 (pri 3), rid0 first;
    # free pool = 1 block -> the tier-0 backlog [1, 2, 3] is trimmed
    # latest-arrival-first until its demand fits: rid3 and rid2 shed
    assert s.stats.shed == 2
    assert {r.rid for r in s.shed_requests} == {2, 3}
    done += s.drain()
    assert s.stats.completed == 4
    assert {r.rid for r in done} == {0, 1, 4, 5}


def test_memory_pressure_shed_disabled_by_default(paged_factory):
    eng, cfg = paged_factory(batch=8, num_blocks=5)
    s = Scheduler(eng, policy="priority")    # no pressure_shed
    for r in _reqs(cfg, [5] * 6, max_new=2):
        assert s.submit(r)
    done = s.drain()
    assert s.stats.shed == 0 and s.stats.completed == 6


def test_drain_readmits_engine_preempted_requests(paged_factory):
    """Regression: a request preempted inside the engine (total stall)
    must be re-admitted by the scheduler even after its own queue has
    drained — tick() used to skip add_requests on an empty batch and
    drain() would spin forever on engine.waiting."""
    eng, cfg = paged_factory(batch=2, block_size=4, num_blocks=4)  # 3 blocks
    s = Scheduler(eng)
    reqs = _reqs(cfg, [4, 4], max_new=8)     # forces a total stall
    for r in reqs:
        assert s.submit(r)
    done = s.drain()
    assert len(done) == 2
    assert eng.metrics["preemptions"] >= 1
    assert all(len(r.out_tokens) == 8 for r in reqs)
    assert eng.waiting == 0 and eng.active == 0


def test_pool_occupancy_visible_to_scheduler(paged_factory):
    eng, cfg = paged_factory(batch=4)
    s = Scheduler(eng)
    assert eng.memory_pressure() == 0.0
    for r in _reqs(cfg, [5, 5], max_new=3):
        s.submit(r)
    s.tick()
    assert 0.0 < eng.memory_pressure() < 1.0
    assert eng.pool_stats()["used"] == 2
    s.drain()
    assert eng.memory_pressure() == 0.0


def test_plan_ahead_caches_admission_costs(engine_factory):
    """Candidates planned during the in-flight device window are
    consumed by later fills without re-walking admission costs: a
    non-sharing engine prices admission as a pure function of the
    request, so its plans never go stale."""
    eng, cfg = engine_factory(batch=1, prefix_sharing=False)
    s = Scheduler(eng)
    for r in _reqs(cfg, [8, 10, 6], max_new=2):
        s.submit(r)
    assert s.plan_ahead() == 3
    assert s.plan_ahead() == 0           # cached and still valid
    s.drain()
    assert s.stats.plan_hits == 3        # every fill hit the plan cache
    assert s.stats.planned_ahead == 3
    assert s.stats.completed == 3


def test_plan_goes_stale_when_prefix_index_can_move(engine_factory):
    """A prefix-sharing engine's admission costs read the prefix index,
    so any pool mutation must invalidate cached plans — re-planned on
    the next window, never served stale."""
    eng, cfg = engine_factory(batch=2)
    assert eng.prefix_sharing
    s = Scheduler(eng)
    (req,) = _reqs(cfg, [8], max_new=2)
    s.submit(req)
    assert s.plan_ahead() == 1
    eng.pool.version += 1                # what any alloc/free/register does
    assert s.plan_ahead() == 1           # stale -> re-planned, not reused
    s.drain()
    assert s.stats.completed == 1


def test_slo_miss_counted(engine_factory):
    eng, cfg = engine_factory(batch=1)
    eng.clock = vc = VirtualClock(start=1000.0)
    s = Scheduler(eng, policy="fifo")        # fifo still tracks SLO stats
    (req,) = _reqs(cfg, [8], max_new=2)
    req.deadline_s = vc.now() + 5.0
    s.submit(req)
    vc.advance(10.0)                         # SLO lapses while in flight
    s.drain()
    assert s.stats.slo_misses == 1
    assert s.stats.slo_hits == 0


# ------------------------------------------------ percentile (nearest-rank)
def test_percentile_empty_is_zero():
    from repro.serve.scheduler import SchedulerStats
    assert SchedulerStats().percentile(0.5) == 0.0


def test_percentile_single_sample_any_q():
    from repro.serve.scheduler import SchedulerStats
    st = SchedulerStats(latencies_s=[0.42])
    for q in (0.01, 0.5, 0.99, 1.0):
        assert st.percentile(q) == 0.42


def test_percentile_nearest_rank_even_n():
    from repro.serve.scheduler import SchedulerStats
    # 10 samples: p50 is the 5th smallest (ceil(0.5*10)=5). The old
    # int(q*n) index read the 6th — one past the rank.
    st = SchedulerStats(latencies_s=[float(i) for i in range(10, 0, -1)])
    assert st.percentile(0.50) == 5.0
    assert st.percentile(0.90) == 9.0     # ceil(9.0) = 9 -> 9th smallest
    assert st.percentile(0.99) == 10.0    # ceil(9.9) = 10 -> max


def test_percentile_small_sample_not_biased_to_max():
    from repro.serve.scheduler import SchedulerStats
    # 4 samples: the old index hit the max for every q >= 0.75; the
    # nearest rank for p75 is the 3rd smallest
    st = SchedulerStats(latencies_s=[4.0, 1.0, 3.0, 2.0])
    assert st.percentile(0.75) == 3.0
    assert st.percentile(0.76) == 4.0     # ceil(3.04) = 4 -> max
    assert st.percentile(0.25) == 1.0
    assert st.percentile(1.0) == 4.0


def test_percentile_tiny_q_clamps_to_min():
    from repro.serve.scheduler import SchedulerStats
    st = SchedulerStats(latencies_s=[2.0, 1.0, 3.0])
    assert st.percentile(0.0) == 1.0      # rank clamps to 1, not 0
    assert st.percentile(1e-9) == 1.0
