"""Scheduler: admission, continuous batching, SPF vs FIFO, bounded queue."""
import dataclasses

import jax
import pytest

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import Scheduler


@pytest.fixture(scope="module")
def engine_factory():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jax.numpy.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    def make(batch=2, max_seq=64):
        return ServingEngine(model, params, batch_size=batch,
                             max_seq=max_seq), cfg
    return make


def _reqs(cfg, lens, max_new=3):
    rng = jax.random.key(1)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=max_new,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist()))
    return out


def test_drain_completes_all(engine_factory):
    eng, cfg = engine_factory()
    s = Scheduler(eng)
    for r in _reqs(cfg, [8, 12, 8, 10, 6]):
        assert s.submit(r)
    done = s.drain()
    assert len(done) == 5
    assert s.stats.completed == 5
    assert all(len(r.out_tokens) == 3 for r in done)
    assert s.stats.queue_peak >= 3          # engine batch=2, 5 submitted


def test_bounded_queue_rejects(engine_factory):
    eng, cfg = engine_factory()
    s = Scheduler(eng, max_queue=2)
    reqs = _reqs(cfg, [8] * 4)
    assert s.submit(reqs[0]) and s.submit(reqs[1])
    assert not s.submit(reqs[2])
    assert s.stats.rejected == 1
    s.drain()
    assert s.stats.completed == 2


def test_spf_prefers_short_prompts(engine_factory):
    eng, cfg = engine_factory(batch=1)
    s = Scheduler(eng, policy="spf")
    reqs = _reqs(cfg, [32, 4, 16], max_new=2)
    for r in reqs:
        s.submit(r)
    order = []
    while s.queue or any(r is not None for r in eng.slot_req):
        for r in s.tick():
            order.append(r.rid)
    assert order[0] == 1                    # shortest (len 4) served first
    assert s.stats.completed == 3
