"""On-device sampler: greedy/temperature/top-k semantics, counter-based
key determinism, logprobs, and the speculative acceptance rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import sampling
from repro.serve.sampling import (GREEDY, SamplingParams, draft_propose,
                                  sample, speculative_accept)


def _logits(B, V, seed=0):
    return jax.random.normal(jax.random.key(seed), (B, V)) * 3.0


def _rows(n, temp=0.0, top_k=0, seed=0, ctr=0):
    return (jnp.full((n,), temp, jnp.float32),
            jnp.full((n,), top_k, jnp.int32),
            jnp.full((n,), seed, jnp.int32),
            jnp.full((n,), ctr, jnp.int32))


def test_greedy_is_argmax_with_logprob():
    lg = _logits(4, 33)
    toks, lps = sample(lg, *_rows(4))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(lg, axis=-1)))
    ref = jax.nn.log_softmax(lg, axis=-1)
    expect = np.asarray(ref)[np.arange(4), np.asarray(toks)]
    np.testing.assert_allclose(np.asarray(lps), expect, rtol=1e-6)


def test_sampled_deterministic_per_seed_and_counter():
    lg = _logits(2, 50)
    a, _ = sample(lg, *_rows(2, temp=0.9, seed=7, ctr=3))
    b, _ = sample(lg, *_rows(2, temp=0.9, seed=7, ctr=3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a different seed (or counter) is a different stream: over several
    # draws at least one token must differ
    diff = False
    for ctr in range(6):
        x, _ = sample(lg, *_rows(2, temp=0.9, seed=7, ctr=ctr))
        y, _ = sample(lg, *_rows(2, temp=0.9, seed=8, ctr=ctr))
        diff |= bool(np.any(np.asarray(x) != np.asarray(y)))
    assert diff


def test_top_k_restricts_support():
    lg = _logits(1, 64, seed=3)
    order = np.argsort(-np.asarray(lg)[0])
    allowed = set(order[:5].tolist())
    for ctr in range(20):
        (tok,), _ = sample(lg, *_rows(1, temp=1.5, top_k=5, ctr=ctr))
        assert int(tok) in allowed
    # top_k=1 is greedy whatever the temperature
    (tok,), _ = sample(lg, *_rows(1, temp=5.0, top_k=1, ctr=9))
    assert int(tok) == int(order[0])


def test_logprob_is_raw_model_logprob_even_when_shaped():
    """Temperature/top-k shape the DRAW; the reported logprob stays the
    raw log-softmax of the chosen token."""
    lg = _logits(1, 40, seed=5)
    (tok,), (lp,) = sample(lg, *_rows(1, temp=2.0, top_k=3, ctr=1))
    ref = jax.nn.log_softmax(lg[0])[int(tok)]
    assert float(lp) == pytest.approx(float(ref), rel=1e-6)


def test_draft_propose_greedy_and_probs_shape():
    lg = _logits(3, 20, seed=9)
    toks, probs = draft_propose(lg, *_rows(3), jnp.zeros(3, jnp.int32))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(lg, axis=-1)))
    assert probs.shape == (3, 20)
    np.testing.assert_allclose(np.asarray(jnp.sum(probs, -1)), 1.0,
                               rtol=1e-5)


# ---------------------------------------------------- speculative accept
def _accept(tlogits, dprobs, proposed, n_spec, temp=0.0, seed=0, ctr=0):
    B = tlogits.shape[0]
    return speculative_accept(
        tlogits, dprobs, jnp.asarray(proposed, jnp.int32),
        jnp.asarray(n_spec, jnp.int32), *_rows(B, temp=temp, seed=seed,
                                               ctr=ctr))


def test_greedy_accept_counts_leading_argmax_matches():
    V, k = 17, 3
    tl = jax.random.normal(jax.random.key(2), (1, k + 1, V))
    am = np.asarray(jnp.argmax(tl, axis=-1))[0]           # (k+1,)
    dp = jnp.full((1, k, V), 1.0 / V)
    # proposals: first matches, second diverges
    proposed = [[int(am[0]), int((am[1] + 1) % V), int(am[2])]]
    a, toks, lps = _accept(tl, dp, proposed, [k])
    assert int(a[0]) == 1
    # committed: the accepted proposal then the correction = argmax at 1
    assert np.asarray(toks)[0, :2].tolist() == [int(am[0]), int(am[1])]
    ref = jax.nn.log_softmax(tl[0, 1])[int(am[1])]
    assert float(lps[0, 1]) == pytest.approx(float(ref), rel=1e-6)


def test_greedy_accept_all_plus_bonus():
    V, k = 11, 2
    tl = jax.random.normal(jax.random.key(4), (1, k + 1, V))
    am = np.asarray(jnp.argmax(tl, axis=-1))[0]
    dp = jnp.full((1, k, V), 1.0 / V)
    a, toks, _ = _accept(tl, dp, [[int(am[0]), int(am[1])]], [k])
    assert int(a[0]) == k
    assert np.asarray(toks)[0].tolist() == [int(x) for x in am]


def test_rider_row_gets_exactly_the_bonus():
    """n_spec = 0 (a non-speculating rider): zero proposals accepted,
    the bonus is the position-0 sample — the plain decode step."""
    V, k = 9, 3
    tl = jax.random.normal(jax.random.key(6), (1, k + 1, V))
    dp = jnp.full((1, k, V), 1.0 / V)
    a, toks, _ = _accept(tl, dp, [[1, 2, 3]], [0])
    assert int(a[0]) == 0
    assert int(np.asarray(toks)[0, 0]) == int(jnp.argmax(tl[0, 0]))


def test_sampled_accept_identical_dists_accepts_everything():
    """p == q makes the acceptance ratio 1: every proposal commits, so a
    perfect draft loses nothing even in sampled mode."""
    V, k = 23, 3
    tl = jax.random.normal(jax.random.key(8), (2, k + 1, V)) * 2.0
    temp = 0.7
    shaped = jax.vmap(jax.vmap(
        lambda l: sampling._shaped_logits(l, jnp.float32(temp),
                                          jnp.int32(0))))(tl)
    probs = jax.nn.softmax(shaped, axis=-1)
    # propose BY SAMPLING from q = p, any tokens: ratio p/q == 1 always
    proposed = np.asarray(jnp.argmax(probs[:, :k], axis=-1))
    a, toks, _ = _accept(tl, probs[:, :k], proposed, [k, k], temp=temp,
                         seed=3, ctr=1)
    assert np.asarray(a).tolist() == [k, k]


def test_sampled_accept_zero_prob_proposal_rejected():
    """A proposal the target gives ~zero probability is rejected and the
    correction comes from the residual (never the rejected token)."""
    V, k = 12, 2
    base = np.full((1, k + 1, V), 0.0, np.float32)
    base[:, :, 4] = 9.0                     # target mass concentrated on 4
    tl = jnp.asarray(base)
    dp = np.full((1, k, V), 1e-6, np.float32)
    dp[:, :, 7] = 1.0                       # draft proposes 7 with mass ~1
    a, toks, _ = _accept(tl, jnp.asarray(dp), [[7, 7]], [k], temp=1.0,
                         seed=5, ctr=2)
    assert int(a[0]) == 0
    assert int(np.asarray(toks)[0, 0]) == 4


def test_sampling_params_defaults():
    assert GREEDY.greedy and GREEDY.temperature == 0.0
    assert not SamplingParams(temperature=0.5).greedy
