"""LM PaaS wiring: engine replicas behind the balancer/supervisor,
ServiceError semantics for rejection and shedding."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core.services import RequestError, ServiceError
from repro.core.supervisor import Supervisor
from repro.models.model import build_model
from repro.serve.clock import VirtualClock
from repro.serve.service import LMReplica, make_lm_service


@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_lm_service_serves_through_balancer_and_supervisor(stack):
    cfg, model, params = stack
    sup = Supervisor()
    svc = make_lm_service("lm", model, params, n_replicas=2, batch_size=2,
                          max_seq=64, balancer_policy="least_loaded",
                          with_backup=False, supervisor=sup)
    sup.start_all()
    out = svc({"prompt": [5, 6, 7], "max_new_tokens": 3})
    assert len(out["tokens"]) == 3
    assert out["replica"].startswith("lm/")
    st = sup.status()["lm"]
    assert st["healthy_replicas"] == 2
    assert st["upstream"]["served"] == 1


def test_lm_replica_client_errors_are_request_errors(stack):
    """Oversized prompts / expired deadlines are the CLIENT's fault —
    raised as RequestError so the balancer neither retries them nor
    counts them against replica health."""
    cfg, model, params = stack
    svc = make_lm_service("lm", model, params, n_replicas=1, batch_size=1,
                          max_seq=16)
    svc.start()
    rep = svc.replicas[0].handler
    with pytest.raises(RequestError, match="max_seq"):
        rep({"prompt": [3] * 50})
    with pytest.raises(RequestError, match="expired"):
        rep({"prompt": [3, 4], "deadline_s": 0.0})


def test_client_error_does_not_poison_balancer(stack):
    """One unservable request must not bench healthy replicas: before the
    RequestError split, the balancer retried it max_fails times on EVERY
    replica and took the whole service dark."""
    cfg, model, params = stack
    svc = make_lm_service("lm", model, params, n_replicas=2, batch_size=1,
                          max_seq=16, with_backup=False)
    svc.start()
    with pytest.raises(RequestError):
        svc({"prompt": [3] * 50})            # through the balancer
    assert svc.balancer.stats["failovers"] == 0
    out = svc({"prompt": [5, 6, 7], "max_new_tokens": 2})
    assert len(out["tokens"]) == 2           # service still healthy


def test_lm_replica_shed_is_request_error(stack):
    """A request shed between admission and completion surfaces as
    RequestError (not retryable, not an unpack crash). Driven on the
    virtual clock: a hog occupies the only slot, the victim's deadline
    lapses while it waits in the scheduler queue, and the next fill()
    sheds it at dequeue time."""
    cfg, model, params = stack
    svc = make_lm_service("lm_shed", model, params, n_replicas=1,
                          batch_size=1, max_seq=64, policy="deadline")
    svc.start()
    rep = svc.replicas[0].handler
    vc = VirtualClock(start=1000.0)
    rep.scheduler.engine.clock = vc
    rep.scheduler.clock = vc
    rep.loop.clock = vc
    hog = rep.submit({"prompt": [3, 4], "max_new_tokens": 8})
    rep.loop.run_once()          # hog takes the only slot
    doomed = rep.submit({"prompt": [5, 6, 7], "max_new_tokens": 2,
                         "deadline_s": vc.now() + 1.0})
    rep.loop.run_once()          # doomed queues behind the busy slot
    vc.advance(5.0)              # deadline lapses while queued
    with pytest.raises(RequestError, match="shed"):
        rep.loop.wait(doomed)
    assert len(rep.loop.wait(hog)["tokens"]) == 8   # replica unharmed


def test_lm_replica_queue_full_is_service_error(stack):
    """Queue-full IS retryable backpressure — another replica may have
    room, so it stays a ServiceError."""
    cfg, model, params = stack
    svc = make_lm_service("lm", model, params, n_replicas=1, batch_size=1,
                          max_seq=64, max_queue=1)
    svc.start()
    rep = svc.replicas[0].handler
    rep.scheduler.submit = lambda r: False   # simulate a full queue
    with pytest.raises(ServiceError, match="queue full"):
        rep({"prompt": [3, 4, 5]})


def test_lm_replica_load_reports_queue_and_slots(stack):
    cfg, model, params = stack
    svc = make_lm_service("lm", model, params, n_replicas=1, batch_size=2,
                          max_seq=64)
    rep: LMReplica = svc.replicas[0].handler
    assert rep.load() == 0
    from repro.serve.engine import Request
    rep.scheduler.engine.add_request(Request(rid=1, prompt=[4, 5, 6]))
    rep.scheduler.submit(Request(rid=2, prompt=[4, 5]))
    rep.scheduler.submit(Request(rid=3, prompt=[4, 5]))
    assert rep.load() == 3                   # 1 active slot + 2 queued


def test_bad_sampling_payload_is_a_request_error(stack):
    """A malformed "sampling" dict is the client's fault: it must raise
    RequestError (like oversized prompts), not escape as a replica
    failure the balancer would retry everywhere and hold against
    health."""
    cfg, model, params = stack
    svc = make_lm_service("lm_samp", model, params, n_replicas=1,
                          batch_size=1, max_seq=32)
    with pytest.raises(RequestError, match="bad sampling"):
        svc.replicas[0].handler({"prompt": [5, 6, 7],
                                 "sampling": {"temp": 0.9}})
    out = svc.replicas[0].handler({"prompt": [5, 6, 7],
                                   "max_new_tokens": 2,
                                   "sampling": {"temperature": 0.5,
                                                "seed": 3}})
    assert len(out["tokens"]) == len(out["logprobs"]) == 2


def test_non_dict_sampling_payload_is_a_request_error(stack):
    cfg, model, params = stack
    svc = make_lm_service("lm_samp2", model, params, n_replicas=1,
                          batch_size=1, max_seq=32)
    with pytest.raises(RequestError, match="sampling"):
        svc.replicas[0].handler({"prompt": [5, 6], "sampling": "greedy"})


def test_bad_speculation_payload_is_a_request_error(stack):
    cfg, model, params = stack
    svc = make_lm_service("lm_spec", model, params, n_replicas=1,
                          batch_size=1, max_seq=32)
    with pytest.raises(RequestError, match="speculation"):
        svc.replicas[0].handler({"prompt": [5, 6], "speculation": "2"})
