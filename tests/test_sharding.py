"""Sharding rules: every produced spec divides its dims; fallbacks fire
for the known awkward shapes (whisper/hymba vocab, B=1 long-context)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

import jax

pytestmark = pytest.mark.skipif(
    len(jax.devices()) != 1, reason="rules are validated mesh-free on CPU")

from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import get_config
from repro.sharding.rules import ParallelPlan


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh over fake devices just for spec computation."""
    devs = np.array(jax.devices() * int(np.prod(shape)))[:int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


@pytest.fixture(scope="module")
def plan():
    return ParallelPlan.make(fake_mesh(), get_config("qwen3-4b"), "train")


def spec_divides(spec: P, shape, mesh) -> bool:
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            continue
        names = (entry,) if isinstance(entry, str) else entry
        n = int(np.prod([mesh.shape[a] for a in names]))
        if dim % n:
            return False
    return True


def test_param_specs_always_divide(plan):
    import jax.numpy as jnp
    from repro.models.model import build_model
    for arch in ("qwen3-4b", "whisper-tiny", "hymba-1.5b",
                 "kimi-k2-1t-a32b", "grok-1-314b"):
        cfg = get_config(arch)
        p = ParallelPlan.make(plan.mesh, cfg, "train")
        shapes = jax.eval_shape(build_model(cfg).init, jax.random.key(0))
        leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in leaves:
            spec = p.param_spec(path, leaf.shape)
            assert spec_divides(spec, leaf.shape, plan.mesh), \
                (arch, path, leaf.shape, spec)


def test_non_divisible_vocab_replicates(plan):
    # whisper vocab 51865 and hymba 32001 are not divisible by 16
    for arch in ("whisper-tiny", "hymba-1.5b"):
        cfg = get_config(arch)
        p = ParallelPlan.make(plan.mesh, cfg, "train")
        spec = p.param_spec(("embed",), (cfg.vocab_size, cfg.d_model))
        assert spec[0] is None, arch


def test_moe_mode_selection(plan):
    kimi = ParallelPlan.make(plan.mesh, get_config("kimi-k2-1t-a32b"),
                             "train")
    assert kimi.moe_mode == "ep"       # 384 % 16 == 0
    grok = ParallelPlan.make(plan.mesh, get_config("grok-1-314b"), "train")
    assert grok.moe_mode == "tp"       # 8 < 16


def test_batch1_long_context_shards_sequence(plan):
    cfg = get_config("qwen3-4b")
    p = ParallelPlan.make(plan.mesh, cfg, "decode")
    spec = p.cache_spec(("cache", "k"), (36, 1, 524288, 8, 128))
    # batch unshardable -> sequence spread over both axes
    assert spec[1] is None
    assert spec[2] == ("data", "model")
    spec2 = p.cache_spec(("cache", "k"), (36, 128, 32768, 8, 128))
    assert spec2[1] == "data" and spec2[2] == "model"


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=8192),
       st.integers(min_value=1, max_value=8192))
def test_any_matrix_gets_valid_spec(d1, d2):
    plan = ParallelPlan.make(fake_mesh(), get_config("qwen3-4b"), "train")
    spec = plan.param_spec(("blocks", "attn", "w_q"), (36, d1, d2))
    assert spec_divides(spec, (36, d1, d2), plan.mesh)


def test_multipod_fsdp_axes():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    plan = ParallelPlan.make(mesh, get_config("qwen3-4b"), "train")
    assert plan.batch_axes == ("pod", "data")
    spec = plan.param_spec(("blocks", "ffn", "w_in"), (36, 2560, 9728))
    assert spec == P(None, ("pod", "data"), "model")
