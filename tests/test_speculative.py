"""Speculative draft-and-verify decode: the multi-token verify path's
differential property (verify(k) == k+1 sequential decode steps), the
engine-level greedy bit-identity grid, rollback safety under churn
(including the co-holder-KV hypothesis property), and the serving knobs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine
from repro.serve.sampling import SamplingParams


@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reqs(cfg, lens, max_new=6, seed=1, **kw):
    rng = jax.random.key(seed)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=max_new,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist(), **kw))
    return out


def _shared_reqs(cfg, n, prefix_len=20, suffix_len=3, max_new=6, seed=5,
                 **kw):
    rng = jax.random.key(seed)
    rng, k = jax.random.split(rng)
    common = jax.random.randint(k, (prefix_len,), 2, cfg.vocab_size).tolist()
    out = []
    for i in range(n):
        rng, k = jax.random.split(rng)
        sfx = jax.random.randint(k, (suffix_len,), 2,
                                 cfg.vocab_size).tolist()
        out.append(Request(rid=i, prompt=common + sfx, max_new_tokens=max_new,
                           **kw))
    return out


# =============================================== verify-path differential
def _prefill_stripe(model, params, toks, capacity):
    cache = model.init_cache(toks.shape[0], capacity)
    _, pref = model.prefill(params, {"tokens": toks})
    for key in cache:
        cache[key] = jax.lax.dynamic_update_slice(
            cache[key], pref[key].astype(cache[key].dtype), (0,) * 5)
    return cache


def _seq_logits(model, params, win, cache, lens, **kw):
    """k+1 sequential decode_steps — the oracle the verify step must
    reproduce."""
    outs = []
    for j in range(win.shape[1]):
        lg, cache = model.decode_step(params, win[:, j:j + 1], cache,
                                      lens + j, **kw)
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1), cache


def test_verify_matches_sequential_decode_stripe(stack):
    """f32-tight: one q_len=k+1 verify == k+1 single-token steps, logits
    AND resulting cache, every row at its own length."""
    cfg, model, params = stack
    B, P, S = 3, 9, 4
    toks = jax.random.randint(jax.random.key(1), (B, P), 2, cfg.vocab_size)
    win = jax.random.randint(jax.random.key(2), (B, S), 2, cfg.vocab_size)
    lens = jnp.full((B,), P, jnp.int32)
    seq, c_seq = _seq_logits(model, params, win,
                             _prefill_stripe(model, params, toks, 32), lens)
    ver, c_ver = model.verify_step(params, win,
                                   _prefill_stripe(model, params, toks, 32),
                                   lens)
    np.testing.assert_allclose(np.asarray(ver), np.asarray(seq),
                               rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(jnp.argmax(ver, -1))
                  == np.asarray(jnp.argmax(seq, -1)))
    for key in c_seq:
        np.testing.assert_allclose(np.asarray(c_ver[key]),
                                   np.asarray(c_seq[key]),
                                   rtol=2e-5, atol=2e-5)


def test_verify_matches_sequential_decode_bf16(stack):
    """Same property at bf16 storage precision, looser tolerance."""
    cfg, _, _ = stack
    cfg16 = dataclasses.replace(cfg, dtype=jnp.bfloat16)
    model = build_model(cfg16)
    params = model.init(jax.random.key(0))
    B, P, S = 2, 7, 3
    toks = jax.random.randint(jax.random.key(3), (B, P), 2, cfg.vocab_size)
    win = jax.random.randint(jax.random.key(4), (B, S), 2, cfg.vocab_size)
    lens = jnp.full((B,), P, jnp.int32)
    seq, _ = _seq_logits(model, params, win,
                         _prefill_stripe(model, params, toks, 32), lens)
    ver, _ = model.verify_step(params, win,
                               _prefill_stripe(model, params, toks, 32),
                               lens)
    np.testing.assert_allclose(np.asarray(ver, np.float32),
                               np.asarray(seq, np.float32),
                               rtol=2e-2, atol=2e-2)


def _paged_setup(model, params, toks, bs, num_blocks):
    """Prefill into a block pool; returns (cache, table, lens)."""
    B, P = toks.shape
    cache = model.init_paged_cache(num_blocks, bs)
    _, pref = model.prefill(params, {"tokens": toks})
    n_blk = -(-P // bs)
    table = np.zeros((B, num_blocks), np.int32)
    nxt = 1
    for b in range(B):
        for i in range(n_blk):
            table[b, i] = nxt
            lo, hi = i * bs, min((i + 1) * bs, P)
            for key in cache:
                cache[key] = cache[key].at[:, nxt, : hi - lo].set(
                    pref[key][:, b, lo:hi].astype(cache[key].dtype))
            nxt += 1
    return cache, jnp.asarray(table), jnp.full((B,), P, jnp.int32)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["gather", "kernel"])
def test_verify_matches_sequential_decode_paged(stack, use_kernel):
    """The paged verify (jnp gather AND the fused multi-token Pallas
    window kernel — ONE launch for the whole verify window, interpret
    mode on CPU) against sequential paged decode."""
    cfg, model, params = stack
    B, P, S, bs = 2, 10, 3, 4
    toks = jax.random.randint(jax.random.key(5), (B, P), 2, cfg.vocab_size)
    win = jax.random.randint(jax.random.key(6), (B, S), 2, cfg.vocab_size)
    cache, table, lens = _paged_setup(model, params, toks, bs,
                                      num_blocks=16)
    seq, _ = _seq_logits(model, params, win, cache, lens,
                         block_table=table, paged_kernel=use_kernel)
    cache2, table2, _ = _paged_setup(model, params, toks, bs,
                                     num_blocks=16)
    ver, _ = model.verify_step(params, win, cache2, lens,
                               block_table=table2, paged_kernel=use_kernel)
    np.testing.assert_allclose(np.asarray(ver), np.asarray(seq),
                               rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(jnp.argmax(ver, -1))
                  == np.asarray(jnp.argmax(seq, -1)))


def test_verify_shared_prefix_blocks_and_scratch_diversion(stack):
    """Two rows whose tables alias the SAME physical prefix blocks: the
    verify window must read through the shared blocks correctly, and a
    row with a zero n_write (a rider) must leave every owned block
    byte-identical — its scatter is diverted to scratch."""
    cfg, model, params = stack
    P, S, bs = 8, 3, 4
    tok_row = jax.random.randint(jax.random.key(7), (1, P), 2,
                                 cfg.vocab_size)
    toks = jnp.concatenate([tok_row, tok_row], axis=0)     # same prompt
    win = jax.random.randint(jax.random.key(8), (2, S), 2, cfg.vocab_size)
    cache, _, lens = _paged_setup(model, params, tok_row, bs, num_blocks=16)
    # both rows read blocks 1..2 (the shared prefix); each owns one tail
    table = np.zeros((2, 16), np.int32)
    table[0, :2] = [1, 2]
    table[1, :2] = [1, 2]
    table[0, 2] = 3                                        # row 0's tail
    table[1, 2] = 4                                        # row 1's tail
    ver, cache2 = model.verify_step(
        params, win, {k: v for k, v in cache.items()}, lens,
        block_table=jnp.asarray(table),
        n_write=jnp.asarray([S, 0], jnp.int32))            # row 1 rides
    # row 1's "owned" block 4 untouched; shared prefix blocks untouched
    for key in cache:
        np.testing.assert_array_equal(np.asarray(cache2[key][:, 4]),
                                      np.asarray(cache[key][:, 4]))
        np.testing.assert_array_equal(np.asarray(cache2[key][:, 1:3]),
                                      np.asarray(cache[key][:, 1:3]))
    # row 0 (writing) equals its sequential oracle at every position;
    # row 1's outputs are only valid at position 0 (rider semantics)
    cache3, table3, _ = _paged_setup(model, params, tok_row, bs,
                                     num_blocks=16)
    seq, _ = _seq_logits(model, params, win[:1], cache3,
                         jnp.full((1,), P, jnp.int32), block_table=table3)
    np.testing.assert_allclose(np.asarray(ver[0]), np.asarray(seq[0]),
                               rtol=2e-5, atol=2e-5)


def test_verify_rejects_recurrent_families(stack):
    cfg = dataclasses.replace(get_config("rwkv6-1.6b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(1, 16)
    with pytest.raises(ValueError, match="unsupported for family"):
        model.verify_step(params, jnp.ones((1, 3), jnp.int32), cache,
                          jnp.asarray([4], jnp.int32))


# ========================================== engine greedy bit-identity grid
GRID = [
    dict(paged=True, block_size=8),
    dict(paged=True, block_size=8, use_kernel=True),
    dict(paged=True, block_size=4, num_blocks=12),      # tight pool
    dict(paged=False),
]
GRID_IDS = ["paged", "kernel", "tight-pool", "stripe"]


@pytest.mark.parametrize("cfg_kw", GRID, ids=GRID_IDS)
def test_greedy_spec_streams_bit_identical(stack, cfg_kw):
    """THE acceptance regression: greedy speculative decode emits
    bit-identical streams to non-speculative decode — mixed lengths,
    every engine config, a self-draft (high acceptance) AND a
    different-weights draft (near-zero acceptance)."""
    cfg, model, params = stack
    lens = [5, 11, 7, 14]
    base = _reqs(cfg, lens)
    e0 = ServingEngine(model, params, batch_size=4, max_seq=64, **cfg_kw)
    e0.run(list(base))
    for tag, dparams in (("self", params),
                         ("cold", model.init(jax.random.key(9)))):
        spec = _reqs(cfg, lens)
        e1 = ServingEngine(model, params, batch_size=4, max_seq=64,
                           draft_model=model, draft_params=dparams,
                           speculation=3, **cfg_kw)
        e1.run(list(spec))
        for a, b in zip(base, spec):
            assert a.out_tokens == b.out_tokens, (tag, a.rid)
            np.testing.assert_allclose(a.out_logprobs, b.out_logprobs,
                                       rtol=1e-5, atol=1e-5)
        assert e1.metrics["verify_steps"] > 0
        if e1.paged:
            assert e1.pool.available == e1.pool.total
            e1.pool.check()
    # the self-draft actually speculates: >1 token per target step
    assert e1.metrics["spec_proposed"] > 0


def test_greedy_spec_shared_prefix_streams(stack):
    """Greedy bit-identity through prefix sharing: shared admissions,
    catch-up riders, and CoW all compose with speculation."""
    cfg, model, params = stack
    a = _shared_reqs(cfg, 4)
    b = _shared_reqs(cfg, 4)
    e0 = ServingEngine(model, params, batch_size=4, max_seq=64,
                       paged=True, block_size=8, prefix_sharing=True)
    e1 = ServingEngine(model, params, batch_size=4, max_seq=64,
                       paged=True, block_size=8, prefix_sharing=True,
                       draft_model=model, draft_params=params,
                       speculation=3)
    e0.run(list(a))
    e1.run(list(b))
    for x, y in zip(a, b):
        assert x.out_tokens == y.out_tokens, x.rid
    assert e1.metrics["shared_admissions"] >= 1
    assert e1.metrics["spec_accepted"] > 0
    assert e1.pool.available == e1.pool.total
    e1.pool.check()


def test_self_draft_accepts_everything_and_multiplies_tokens(stack):
    """A draft with the target's own weights proposes the target argmax:
    greedy acceptance is total, so tokens per target step ~ k+1."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=8, draft_model=model,
                        draft_params=params, speculation=3)
    reqs = _reqs(cfg, [6, 9], max_new=8)
    eng.run(list(reqs))
    m = eng.metrics
    assert m["spec_accepted"] == m["spec_proposed"] > 0
    emitted = sum(len(r.out_tokens) for r in reqs)
    # prefill emits one per request; every verify step nets > 1 token
    assert (emitted - len(reqs)) / m["decode_steps"] > 1.0


def test_spec_rollback_returns_watermark_blocks(stack):
    """A rejecting draft makes the engine allocate window blocks and
    roll them back: the pool never leaks and streams stay correct."""
    cfg, model, params = stack
    cold = model.init(jax.random.key(11))
    eng = ServingEngine(model, params, batch_size=1, max_seq=64,
                        paged=True, block_size=4, draft_model=model,
                        draft_params=cold, speculation=3)
    (req,) = _reqs(cfg, [7], max_new=10)
    eng.run([req])
    m = eng.metrics
    assert m["spec_blocks_rolled_back"] > 0
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    base = ServingEngine(model, params, batch_size=1, max_seq=64,
                         paged=True, block_size=4)
    (d,) = base.run([Request(rid=100, prompt=list(req.prompt),
                             max_new_tokens=10)])
    assert d.out_tokens == req.out_tokens


def test_per_request_speculation_opt_out(stack):
    """Request.speculation=0 rides every verify batch non-speculatively;
    its stream is still the plain greedy stream."""
    cfg, model, params = stack
    lens = [6, 8]
    base = _reqs(cfg, lens)
    ServingEngine(model, params, batch_size=2, max_seq=64).run(list(base))
    spec = _reqs(cfg, lens)
    spec[1].speculation = 0
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        draft_model=model, draft_params=params,
                        speculation=3)
    eng.run(list(spec))
    for a, b in zip(base, spec):
        assert a.out_tokens == b.out_tokens, a.rid
    # the opted-out request emitted one token per step: its stream is as
    # long as the opted-in one but took proportionally more steps
    assert eng.metrics["spec_proposed"] > 0


def test_speculation_validation(stack):
    cfg, model, params = stack
    with pytest.raises(ValueError, match="draft model"):
        ServingEngine(model, params, batch_size=1, max_seq=32,
                      speculation=2)
    mo_cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                                 dtype=jnp.float32)
    mo = build_model(mo_cfg)
    mo_params = mo.init(jax.random.key(0))
    with pytest.raises(ValueError, match="MoE"):
        ServingEngine(mo, mo_params, batch_size=1, max_seq=32,
                      draft_model=mo, draft_params=mo_params, speculation=2)
    r_cfg = dataclasses.replace(get_config("rwkv6-1.6b").reduced(),
                                dtype=jnp.float32)
    rm = build_model(r_cfg)
    rp = rm.init(jax.random.key(0))
    with pytest.raises(ValueError, match="pure-attention"):
        ServingEngine(rm, rp, batch_size=1, max_seq=32, draft_model=model,
                      draft_params=params, speculation=2)
    # a recurrent DRAFT is rejected too: the runner's rollback is
    # truncate-only stripe semantics, which recurrent state cannot obey
    cfg_ok = dataclasses.replace(get_config("qwen3-4b").reduced(),
                                 dtype=jnp.float32)
    tm = build_model(cfg_ok)
    with pytest.raises(ValueError, match="draft model"):
        ServingEngine(tm, tm.init(jax.random.key(0)), batch_size=1,
                      max_seq=32, draft_model=rm, draft_params=rp,
                      speculation=2)


def test_blocks_needed_charges_spec_watermark(stack):
    """The scheduler's block gate must include the speculative window,
    or a fill batch admits and instantly mass-parks."""
    cfg, model, params = stack
    plain = ServingEngine(model, params, batch_size=2, max_seq=64,
                          paged=True, block_size=4)
    spec = ServingEngine(model, params, batch_size=2, max_seq=64,
                         paged=True, block_size=4, draft_model=model,
                         draft_params=params, speculation=3)
    (r,) = _reqs(cfg, [8], max_new=8)
    # 8 tokens = 2 blocks; the k+1=4-token window adds one more
    assert plain.blocks_needed(r) == 2
    assert spec.blocks_needed(r) == 3
    r2 = Request(rid=9, prompt=[3] * 8, max_new_tokens=8, speculation=0)
    assert spec.blocks_needed(r2) == 2       # opted out: no watermark


def test_scheduler_drains_speculative_engine(stack):
    from repro.serve.scheduler import Scheduler
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=8, draft_model=model,
                        draft_params=params, speculation=2)
    sched = Scheduler(eng, policy="fifo")
    reqs = _reqs(cfg, [5, 9, 7], max_new=5)
    for r in reqs:
        assert sched.submit(r)
    done = sched.drain()
    assert len(done) == 3
    assert eng.metrics["verify_steps"] > 0
    assert eng.pool.available == eng.pool.total


def test_sampled_spec_reproducible_and_exhaustive(stack):
    """Sampled speculative decode: streams reproduce run-to-run (counter
    keys), logprobs ride along, and every request completes."""
    cfg, model, params = stack
    sp = SamplingParams(temperature=0.8, top_k=12, seed=17)
    outs = []
    for _ in range(2):
        reqs = _reqs(cfg, [6, 9], max_new=7, sampling=sp)
        eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                            paged=True, block_size=8, draft_model=model,
                            draft_params=params, speculation=3)
        eng.run(list(reqs))
        outs.append([r.out_tokens for r in reqs])
        for r in reqs:
            assert len(r.out_tokens) == 7
            assert len(r.out_logprobs) == 7
            assert all(np.isfinite(r.out_logprobs))
    assert outs[0] == outs[1]


# =========================================== rollback churn property
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       block_size=st.sampled_from([4, 8]),
       spec_k=st.integers(min_value=1, max_value=4))
def test_property_spec_rollback_never_corrupts_coholder(stack, seed,
                                                        block_size, spec_k):
    """Hypothesis churn: shared-prefix requests under a TIGHT pool with
    speculation on — CoW, parking, preemption, watermark growth and
    rollback all interleave. Whatever happens, every request's stream
    must equal its uncontended solo run (no co-holder's KV was ever
    touched) and the pool must drain clean."""
    cfg, model, params = stack
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 4))
    reqs = _shared_reqs(cfg, n, prefix_len=int(rng.integers(6, 14)),
                        suffix_len=int(rng.integers(1, 4)),
                        max_new=int(rng.integers(4, 10)),
                        seed=int(rng.integers(0, 2 ** 31)))
    num_blocks = int(rng.integers(7, 13))
    eng = ServingEngine(model, params, batch_size=n, max_seq=64,
                        paged=True, block_size=block_size,
                        num_blocks=num_blocks, prefix_sharing=True,
                        draft_model=model, draft_params=params,
                        speculation=spec_k)
    done = eng.run(list(reqs))
    assert len(done) == len(reqs)
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    for r in reqs:
        solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                             paged=True, block_size=block_size,
                             prefix_sharing=False)
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens)])
        assert d.out_tokens == r.out_tokens, r.rid


# ================================================= logprobs + cached reuse
def test_logprobs_match_manual_log_softmax(stack):
    """The streamed logprob of a greedy token is the raw log-softmax at
    that token — checked against a hand prefill."""
    cfg, model, params = stack
    (req,) = _reqs(cfg, [6], max_new=3)
    eng = ServingEngine(model, params, batch_size=1, max_seq=64)
    eng.run([req])
    toks = jnp.asarray([req.prompt], jnp.int32)
    logits, _ = model.prefill(params, {"tokens": toks})
    ref = jax.nn.log_softmax(logits[0, -1].astype(jnp.float32))
    assert len(req.out_logprobs) == len(req.out_tokens) == 3
    assert req.out_logprobs[0] == pytest.approx(
        float(ref[req.out_tokens[0]]), rel=1e-5)
    assert int(jnp.argmax(logits[0, -1])) == req.out_tokens[0]


def test_sequential_identical_prompts_reuse_cached_blocks(stack):
    """Back-to-back identical prompts (the second submitted AFTER the
    first completed and freed its blocks) still share: the freed chain's
    index entries survive until the memory is recycled, so the second
    admission revives the blocks instead of recomputing the prefill."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=8, prefix_sharing=True)
    (first,) = _reqs(cfg, [20], max_new=4, seed=9)
    eng.run([first])
    assert eng.pool.available == eng.pool.total      # all freed...
    assert eng.pool.cached > 0                       # ...but still indexed
    second = Request(rid=10, prompt=list(first.prompt), max_new_tokens=4)
    eng.run([second])
    assert eng.metrics["shared_admissions"] == 1
    assert eng.metrics["prefill_tokens_shared"] >= 16
    assert second.out_tokens == first.out_tokens
    eng.pool.check()
    # sanity: sharing-off never matches across retirement
    off = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=8, prefix_sharing=False)
    (a,) = _reqs(cfg, [20], max_new=4, seed=9)
    off.run([a])
    b = Request(rid=11, prompt=list(a.prompt), max_new_tokens=4)
    off.run([b])
    assert off.metrics["shared_admissions"] == 0
    assert b.out_tokens == a.out_tokens


def test_revived_blocks_not_double_counted_in_batch_planning(stack):
    """Admission planning charges a cached-block revival once: after the
    planning-time acquire moves the block off the free list, `planned`
    must drop it, or a same-batch follower is gated out of a pool that
    actually has room."""
    cfg, model, params = stack
    # pool of exactly 7: request A uses 3 blocks (20 tokens / bs 8),
    # retires, leaves them cached. Then one batch: A' (revives 2 shared
    # + needs ~2) and B (2 blocks + reserve) — fits ONLY if the revived
    # blocks are not counted both in planned and out of available.
    eng = ServingEngine(model, params, batch_size=4, max_seq=64,
                        paged=True, block_size=8, num_blocks=8,
                        prefix_sharing=True)
    (first,) = _reqs(cfg, [20], max_new=2, seed=13)
    eng.run([first])
    assert eng.pool.cached == 3
    again = Request(rid=20, prompt=list(first.prompt), max_new_tokens=2)
    (other,) = _reqs(cfg, [12], max_new=2, seed=14)
    other.rid = 21
    assert eng.add_requests([again, other]) == 2   # both admitted together
    done = eng.run([])
    assert len(done) == 2
    assert eng.metrics["shared_admissions"] == 1
    eng.pool.check()


def test_sampled_opt_out_stream_independent_of_neighbors(stack):
    """A sampled request that opts out of speculation must emit the same
    stream whether its co-batched neighbor speculates or not: riders
    draw from the TOKEN stream at the plain-step counter, never from the
    verify batch's accept stream."""
    cfg, model, params = stack
    sp = SamplingParams(temperature=0.8, top_k=10, seed=31)
    (plain,) = _reqs(cfg, [6], max_new=6, seed=2, sampling=sp)
    ServingEngine(model, params, batch_size=2, max_seq=64,
                  paged=True, block_size=8).run([plain])
    (rider,) = _reqs(cfg, [6], max_new=6, seed=2, sampling=sp)
    rider.speculation = 0
    (neighbor,) = _reqs(cfg, [9], max_new=6, seed=3)
    neighbor.rid = 50
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=8, draft_model=model,
                        draft_params=params, speculation=3)
    eng.run([rider, neighbor])
    assert eng.metrics["spec_proposed"] > 0      # the neighbor speculated
    assert rider.out_tokens == plain.out_tokens
