"""Benchmark harness CLI: the ``--only`` comma-filter must resolve
loudly — a typo that silently ran zero modules used to read as a green
bench run in CI."""
import sys
from pathlib import Path

import pytest

# benchmarks/ is a plain directory (no __init__.py) at the repo root,
# which isn't on sys.path when pytest runs from tests/
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.run import MODULES, select_modules  # noqa: E402


def test_empty_filter_selects_everything():
    assert select_modules("") == list(MODULES)


def test_substring_filter_selects_matching_modules():
    assert select_modules("paged_kv") == ["bench_paged_kv"]
    assert select_modules("serving,speculative") == [
        "bench_serving", "bench_speculative"]


def test_filter_preserves_module_order_not_filter_order():
    assert select_modules("speculative,serving") == [
        "bench_serving", "bench_speculative"]


def test_unknown_filter_is_a_hard_error():
    with pytest.raises(SystemExit, match="pagedkv.*matches no benchmark"):
        select_modules("pagedkv")


def test_one_bad_filter_fails_even_with_good_ones():
    with pytest.raises(SystemExit, match="nope"):
        select_modules("serving,nope")
