"""NGINX-upstream semantics: round-robin, max_fails/fail_timeout benching,
backup promotion, recovery."""
import pytest

from repro.core.balancer import RoundRobinBalancer
from repro.core.services import Replica, ServiceError


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def mk(name, **kw):
    return Replica(name, handler=lambda p: (name, p), **kw)


def test_round_robin_is_fair():
    reps = [mk("a"), mk("b"), mk("c")]
    lb = RoundRobinBalancer(reps)
    for _ in range(30):
        lb("x")
    assert [r.calls for r in reps] == [10, 10, 10]


def test_failed_primary_is_benched_and_backup_serves():
    clock = FakeClock()
    a, b = mk("a"), mk("backup", backup=True)
    lb = RoundRobinBalancer([a, b], max_fails=3, fail_timeout=15.0,
                            clock=clock)
    a.set_up(False)
    out, _ = lb("x")          # fails over to backup after benching a
    assert out == "backup"
    assert lb.stats["backup_served"] == 1
    # a benched: requests keep landing on backup without touching a
    calls_before = a.calls
    lb("y")
    assert a.calls == calls_before


def test_benched_primary_recovers_after_fail_timeout():
    clock = FakeClock()
    a, b = mk("a"), mk("backup", backup=True)
    lb = RoundRobinBalancer([a, b], max_fails=1, fail_timeout=15.0,
                            clock=clock)
    a.set_up(False)
    lb("x")
    a.set_up(True)
    clock.t = 16.0            # past fail_timeout -> unbenched
    out, _ = lb("y")
    assert out == "a"


def test_backup_not_used_while_primaries_healthy():
    a, b, bk = mk("a"), mk("b"), mk("backup", backup=True)
    lb = RoundRobinBalancer([a, b, bk])
    for _ in range(20):
        lb("x")
    assert bk.calls == 0


def test_all_down_raises():
    clock = FakeClock()
    a, bk = mk("a"), mk("backup", backup=True)
    lb = RoundRobinBalancer([a, bk], max_fails=1, clock=clock)
    a.set_up(False)
    bk.set_up(False)
    with pytest.raises(ServiceError):
        lb("x")


def test_max_fails_window_semantics():
    """Failures older than fail_timeout don't count toward max_fails."""
    clock = FakeClock()
    a, b = mk("a"), mk("b")
    lb = RoundRobinBalancer([a, b], max_fails=3, fail_timeout=15.0,
                            clock=clock)
    st = lb._state[id(a)]
    for i in range(2):
        lb._record_failure(a)
        clock.t += 20.0        # each failure expires before the next
    assert st.benched_until <= clock.t   # never benched


# ------------------------------------------------------------ least-loaded
class _LoadedHandler:
    def __init__(self, load):
        self._load = load
        self.calls = 0

    def load(self):
        return self._load

    def __call__(self, payload):
        self.calls += 1
        return payload


def test_least_loaded_routes_to_idlest_replica():
    busy, idle = _LoadedHandler(5), _LoadedHandler(0)
    reps = [Replica("busy", busy), Replica("idle", idle)]
    lb = RoundRobinBalancer(reps, policy="least_loaded")
    for i in range(8):
        lb(i)
    assert idle.calls == 8 and busy.calls == 0


def test_least_loaded_falls_back_on_plain_handlers():
    """Handlers without load() report 0 -> stable first-candidate pick,
    still correct (no crash, no lost request)."""
    reps = [mk("a"), mk("b")]
    lb = RoundRobinBalancer(reps, policy="least_loaded")
    for i in range(6):
        assert lb(i)[1] == i
    assert reps[0].calls + reps[1].calls == 6
