"""Slot-native serving engine: mixed-length decode equivalence,
device-side admission, EOS early exit, slot recycling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine, _bucket


@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reqs(cfg, lens, max_new=4, stop=(), seed=1):
    rng = jax.random.key(seed)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=max_new, stop_tokens=stop,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist()))
    return out


# ----------------------------------------------------- mixed-length decode
def test_mixed_length_batch_matches_sequential(stack):
    """The headline regression: prompts of different lengths decoding in
    ONE batch emit token-for-token what each emits served alone."""
    cfg, model, params = stack
    lens = [5, 11, 7, 14]
    batched = _reqs(cfg, lens)
    eng = ServingEngine(model, params, batch_size=4, max_seq=64)
    done = eng.run(list(batched))
    assert len(done) == 4
    # every prefill admitted in one batched call would be ideal, but the
    # bucketing may split: what matters is slots decoded together
    assert eng.metrics["decode_steps"] <= 3 * 4  # far fewer than serial

    solo_eng = ServingEngine(model, params, batch_size=1, max_seq=64)
    for r in batched:
        solo = Request(rid=100 + r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens)
        (d,) = solo_eng.run([solo])
        assert d.out_tokens == r.out_tokens, r.rid


def test_mixed_length_matches_sequential_moe_arch(stack):
    """MoE routing shares per-expert capacity across the flattened token
    block, so admission must prefill one row at a time (no padding, no
    co-batching) to stay bit-exact with solo serving.

    Only the first (prefill-produced) token is compared: decode still
    co-batches slots through the shared expert-capacity pool, so later
    tokens may legitimately diverge under expert overflow (see the
    engine module docstring)."""
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, batch_size=3, max_seq=64)
    assert eng._solo_prefill and not eng._paddable
    reqs = _reqs(cfg, [5, 11, 5])
    done = eng.run(list(reqs))
    assert len(done) == 3
    solo_eng = ServingEngine(model, params, batch_size=1, max_seq=64)
    for r in reqs:
        solo = Request(rid=100 + r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens)
        (d,) = solo_eng.run([solo])
        assert d.out_tokens[0] == r.out_tokens[0], r.rid


def test_mixed_length_matches_sequential_recurrent_arch(stack):
    """Same equivalence for a state-cache family (rwkv): exact-length
    grouping instead of bucketed padding."""
    cfg = dataclasses.replace(get_config("rwkv6-1.6b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    reqs = _reqs(cfg, [4, 9, 6])
    eng = ServingEngine(model, params, batch_size=3, max_seq=64)
    done = eng.run(list(reqs))
    assert len(done) == 3
    solo_eng = ServingEngine(model, params, batch_size=1, max_seq=64)
    for r in reqs:
        solo = Request(rid=100 + r.rid, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens)
        (d,) = solo_eng.run([solo])
        assert d.out_tokens == r.out_tokens, r.rid


def test_per_slot_lengths_tracked(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64)
    reqs = _reqs(cfg, [5, 9], max_new=3)
    assert eng.add_requests(list(reqs)) == 2
    assert sorted(eng.slot_len.tolist()) == [5, 9]
    eng.step()
    assert sorted(eng.slot_len.tolist()) == [6, 10]


# ------------------------------------------------------ device-side admit
def test_admission_is_batched_and_device_side(stack):
    """Multiple same-bucket requests prefill as ONE call, and admission
    never materializes a host copy of the full cache."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=4, max_seq=64)
    before = {k: v for k, v in eng.caches.items()}
    reqs = _reqs(cfg, [5, 6, 7, 8])          # all bucket to 8
    assert eng.add_requests(list(reqs)) == 4
    assert eng.metrics["prefills"] == 4
    assert eng.metrics["prefill_batches"] == 1
    # caches stay device arrays (functional update, no np round-trip)
    for k, v in eng.caches.items():
        assert isinstance(v, jax.Array), k
        assert v.shape == before[k].shape


def test_admission_rejects_when_full_and_oversized(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=1, max_seq=64)
    a, b = _reqs(cfg, [5, 5], max_new=2)
    assert eng.add_request(a)
    assert not eng.add_request(b)            # full
    with pytest.raises(ValueError, match="max_seq"):
        eng.add_request(Request(rid=9, prompt=[3] * 100, max_new_tokens=1))


def test_bucketing():
    assert _bucket(3, 256) == 8
    assert _bucket(8, 256) == 8
    assert _bucket(9, 256) == 16
    assert _bucket(200, 256) == 256
    assert _bucket(200, 100) == 100          # capped at capacity


# ------------------------------------------------------------- EOS / stop
def test_stop_token_early_exit(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64)
    (probe,) = _reqs(cfg, [6], max_new=8)
    eng.run([probe])
    assert len(probe.out_tokens) == 8
    stop = probe.out_tokens[2]               # 3rd generated token as "EOS"
    req = Request(rid=1, prompt=list(probe.prompt), max_new_tokens=8,
                  stop_tokens=(stop,))
    (done,) = ServingEngine(model, params, batch_size=2,
                            max_seq=64).run([req])
    assert done.out_tokens[-1] == stop
    assert len(done.out_tokens) == 3 < 8     # exited early, slot freed


def test_stop_token_at_admission(stack):
    """First generated token == stop token: finishes without a decode."""
    cfg, model, params = stack
    (probe,) = _reqs(cfg, [6], max_new=8)
    eng = ServingEngine(model, params, batch_size=1, max_seq=64)
    eng.run([probe])
    req = Request(rid=1, prompt=list(probe.prompt), max_new_tokens=8,
                  stop_tokens=(probe.out_tokens[0],))
    eng2 = ServingEngine(model, params, batch_size=1, max_seq=64)
    (done,) = eng2.run([req])
    assert len(done.out_tokens) == 1
    assert eng2.metrics["decode_steps"] == 0
    assert eng2.metrics["completed"] == 1
    assert eng2.active == 0


# ---------------------------------------------------------- slot recycling
def test_slot_recycling_mid_flight(stack):
    """A short request finishing early frees its slot for a waiting
    request while the long request keeps decoding."""
    cfg, model, params = stack
    short, lng, waiter = _reqs(cfg, [5, 6, 7])
    short.max_new_tokens = 2
    lng.max_new_tokens = 10
    waiter.max_new_tokens = 2
    eng = ServingEngine(model, params, batch_size=2, max_seq=64)
    done = eng.run([short, lng, waiter])
    assert {r.rid for r in done} == {0, 1, 2}
    assert eng.metrics["slot_reuses"] >= 1
    # waiter finished BEFORE the long request: it got the recycled slot
    order = [r.rid for r in done]
    assert order.index(waiter.rid) < order.index(lng.rid)


def test_out_of_capacity_slot_is_retired(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=1, max_seq=16)
    (req,) = _reqs(cfg, [14], max_new=50)
    (done,) = eng.run([req])
    # 14 prompt + 1 at prefill + decode until cache full
    assert len(done.out_tokens) < 50
    assert eng.active == 0


# ------------------------------------------------------------- load probe
def test_engine_load_reports_occupancy(stack):
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=3, max_seq=64)
    assert eng.load() == 0
    eng.add_requests(_reqs(cfg, [5, 6]))
    assert eng.load() == 2


# --------------------------------------------------------------- paged KV
def test_paged_is_default_for_dense_fixed_for_recurrent(stack):
    """Pure-attention caches page; recurrent state keeps the stripe."""
    cfg, model, params = stack
    assert ServingEngine(model, params, batch_size=2, max_seq=64).paged
    rcfg = dataclasses.replace(get_config("rwkv6-1.6b").reduced(),
                               dtype=jnp.float32)
    rmodel = build_model(rcfg)
    rparams = rmodel.init(jax.random.key(0))
    reng = ServingEngine(rmodel, rparams, batch_size=2, max_seq=64)
    assert not reng.paged and reng.pool is None
    with pytest.raises(ValueError, match="pure-attention"):
        ServingEngine(rmodel, rparams, batch_size=2, max_seq=64, paged=True)


def test_paged_matches_fixed_stripe_streams(stack):
    """The tentpole regression: the block-pool layout emits exactly the
    token streams of the fixed-stripe layout it replaces."""
    cfg, model, params = stack
    lens = [5, 14, 9, 17]
    a, b = _reqs(cfg, lens, max_new=6), _reqs(cfg, lens, max_new=6)
    ep = ServingEngine(model, params, batch_size=4, max_seq=64,
                       paged=True, block_size=8)
    ef = ServingEngine(model, params, batch_size=4, max_seq=64, paged=False)
    ep.run(list(a))
    ef.run(list(b))
    for x, y in zip(a, b):
        assert x.out_tokens == y.out_tokens, x.rid


def test_paged_mixed_length_batch_matches_sequential(stack):
    """Batched == sequential bit-exactness holds through the block
    table: slots whose KV is scattered over disjoint pool blocks decode
    together exactly as each decodes alone."""
    cfg, model, params = stack
    lens = [5, 11, 7, 14]
    batched = _reqs(cfg, lens)
    eng = ServingEngine(model, params, batch_size=4, max_seq=64,
                        paged=True, block_size=8)
    done = eng.run(list(batched))
    assert len(done) == 4
    solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                         paged=True, block_size=8)
    for r in batched:
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_paged_moe_solo_prefill_first_token(stack):
    """MoE pages too (its cache is pure {k, v}); the solo-prefill
    admission caveat is orthogonal to the memory layout."""
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        block_size=8)
    assert eng.paged and eng._solo_prefill
    reqs = _reqs(cfg, [5, 11, 5])
    assert len(eng.run(list(reqs))) == 3
    solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                         block_size=8)
    for r in reqs:
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens)])
        assert d.out_tokens[0] == r.out_tokens[0], r.rid


def test_paged_blocks_grow_lazily_and_free_on_eos(stack):
    """A slot pays blocks for its real length only, grows one block at a
    time as decode crosses block boundaries, and returns everything on
    retirement."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=1, max_seq=64,
                        paged=True, block_size=8)
    (req,) = _reqs(cfg, [6], max_new=12)     # 6 + 12 tokens -> 3 blocks
    assert eng.add_requests([req]) == 1
    assert len(eng.slot_blocks[0]) == 1      # ceil(6/8): prompt only
    eng.run([])                              # drain the active slot
    assert eng.metrics["blocks_grown"] == 2  # grew at len 8 and len 16
    assert eng.pool.used == 0
    assert eng.pool.available == eng.pool.total


def _shared_prefix_reqs(cfg, n, prefix_len=20, suffix_len=3, max_new=5,
                        seed=5):
    """n requests sharing a common prefix with distinct random suffixes."""
    rng = jax.random.key(seed)
    rng, k = jax.random.split(rng)
    common = jax.random.randint(k, (prefix_len,), 2, cfg.vocab_size).tolist()
    out = []
    for i in range(n):
        rng, k = jax.random.split(rng)
        sfx = jax.random.randint(k, (suffix_len,), 2, cfg.vocab_size).tolist()
        out.append(Request(rid=i, prompt=common + sfx, max_new_tokens=max_new))
    return out


# ------------------------------------------------ prefix sharing + CoW
def test_shared_prefix_streams_match_unshared(stack):
    """The sharing regression: admissions that reuse resident prefix
    blocks (including in-batch sharing within ONE add_requests call)
    emit exactly the token streams of a sharing-disabled engine."""
    cfg, model, params = stack
    a = _shared_prefix_reqs(cfg, 4)
    b = _shared_prefix_reqs(cfg, 4)
    on = ServingEngine(model, params, batch_size=4, max_seq=64,
                       paged=True, block_size=8, prefix_sharing=True)
    off = ServingEngine(model, params, batch_size=4, max_seq=64,
                        paged=True, block_size=8, prefix_sharing=False)
    on.run(list(a))
    off.run(list(b))
    for x, y in zip(a, b):
        assert x.out_tokens == y.out_tokens, x.rid
    assert on.metrics["shared_admissions"] == 3      # 1 plain + 3 shared
    assert on.metrics["prefill_tokens_shared"] > 0
    assert on.metrics["prefill_tokens_computed"] \
        < off.metrics["prefill_tokens_computed"]
    assert on.pool.available == on.pool.total        # everything returned
    on.pool.check()


def test_shared_tail_block_copy_on_write(stack):
    """A request whose whole prompt is a prefix of a resident sequence
    shares the resident *partial tail* block; its first append would
    land inside that shared block, so it must copy-on-write — and both
    streams must equal their solo runs."""
    cfg, model, params = stack
    rng = jax.random.key(11)
    long = jax.random.randint(rng, (14,), 2, cfg.vocab_size).tolist()
    ra = Request(rid=0, prompt=list(long), max_new_tokens=6)
    rb = Request(rid=1, prompt=list(long[:11]), max_new_tokens=6)
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=8, prefix_sharing=True)
    assert eng.add_requests([ra]) == 1
    assert eng.add_requests([rb]) == 1       # shares block 1 + partial tail
    assert eng.metrics["shared_admissions"] == 1
    eng.run([])
    assert eng.metrics["cow_copies"] >= 1
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    for r in (ra, rb):
        solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                             paged=True, block_size=8, prefix_sharing=False)
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=6)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_shared_blocks_accounted_once(stack):
    """pool_stats/memory_pressure charge a shared block once: logical
    table entries exceed physical used blocks under sharing."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=4, max_seq=64,
                        paged=True, block_size=8, prefix_sharing=True)
    reqs = _shared_prefix_reqs(cfg, 4, prefix_len=16, suffix_len=2,
                               max_new=50)   # keep slots resident
    eng.add_requests(list(reqs))
    stats = eng.pool_stats()
    assert stats["shared"] == 2                   # the 2 prefix blocks
    assert stats["logical_blocks"] > stats["used"]
    # at admission: 3 blocks of the plain request, prefix shared by all
    assert stats["used"] == 3
    for _ in range(3):                            # drain catch-up suffixes
        eng.step()
    stats = eng.pool_stats()
    # physical: 1x prefix (2 blocks, shared by 4) + 4x own tail block
    assert stats["used"] == 2 + 4
    assert stats["logical_blocks"] == 4 * 3 > stats["used"]
    assert eng.memory_pressure() == stats["used"] / stats["total"]
    eng.pool.check()


def test_scheduler_gates_on_post_sharing_cost(stack):
    """A queue of same-prefix requests fits where the worst-case cost
    would not: the block-gated fill charges the post-sharing price."""
    from repro.serve.scheduler import Scheduler
    cfg, model, params = stack
    # pool of 7 blocks; each prompt needs 3 alone (24 tokens / bs=8).
    # Worst-case 4 requests = 12 blocks > 7; post-sharing = 3 + 3x1 = 6.
    eng = ServingEngine(model, params, batch_size=4, max_seq=32,
                        paged=True, block_size=8, num_blocks=8,
                        prefix_sharing=True)
    sched = Scheduler(eng)
    reqs = _shared_prefix_reqs(cfg, 4, prefix_len=22, suffix_len=2,
                               max_new=2)
    for r in reqs:
        assert sched.submit(r)
    done = sched.drain()
    assert len(done) == 4
    assert eng.metrics["shared_admissions"] >= 1
    assert eng.metrics["preemptions"] == 0   # fit without thrash
    eng.pool.check()


def test_park_resume_bit_exact_with_shared_blocks(stack):
    """Pool exhaustion while slots share prefix blocks: parked slots
    resume and all streams stay identical to uncontended runs."""
    cfg, model, params = stack
    reqs = _shared_prefix_reqs(cfg, 3, prefix_len=10, suffix_len=2,
                               max_new=10, seed=21)
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        paged=True, block_size=4, num_blocks=9,
                        prefix_sharing=True)
    done = eng.run(list(reqs))
    assert len(done) == 3
    assert eng.metrics["shared_admissions"] >= 1
    assert eng.metrics["parked_slot_steps"] > 0 \
        or eng.metrics["preemptions"] > 0        # contention actually hit
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    for r in reqs:
        solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                             paged=True, block_size=4, prefix_sharing=False)
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=10)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_preemption_of_shared_holder_keeps_other_side_intact(stack):
    """Recompute-preemption of a slot that shares blocks with a live
    slot frees only its own references — the survivor's stream and the
    evicted request's post-resume stream both stay bit-exact."""
    cfg, model, params = stack
    reqs = _shared_prefix_reqs(cfg, 2, prefix_len=8, suffix_len=1,
                               max_new=10, seed=33)
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=4, num_blocks=7,
                        prefix_sharing=True)
    done = eng.run(list(reqs))
    assert len(done) == 2
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    for r in reqs:
        solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                             paged=True, block_size=4, prefix_sharing=False)
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=10)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_blocks_needed_charges_partial_tail_cow(stack):
    """A match ending inside a shared partial tail must charge the
    imminent copy-on-write block, or a batch of tail-sharing admissions
    all passes the gate and parks on its first decode step."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        paged=True, block_size=8, prefix_sharing=True)
    rng = jax.random.key(17)
    base = jax.random.randint(rng, (12,), 2, cfg.vocab_size).tolist()
    eng.add_requests([Request(rid=0, prompt=list(base), max_new_tokens=40)])
    # full block + 2 tokens of the resident partial tail: 2 - 2 + 1 CoW
    tail_share = Request(rid=1, prompt=list(base[:10]), max_new_tokens=2)
    assert eng.blocks_needed(tail_share) == 1
    # boundary-ended match: the un-shared suffix block is already counted
    boundary = Request(rid=2, prompt=list(base[:8]) + [7, 7, 7],
                       max_new_tokens=2)
    assert eng.blocks_needed(boundary) == 1


def test_long_unshared_suffix_prefills_plain_in_monolithic_mode(stack):
    """LEGACY monolithic mode (prefill_chunk=0): catch-up decode feeds
    the un-shared suffix one token per step there, so a short-prefix/
    long-suffix prompt must NOT engage sharing — one batched prefill
    beats dozens of serial catch-up steps. (With chunked prefill — the
    default — the suffix drains chunk-at-a-time and the bound is gone:
    tests/test_chunked.py::test_long_unshared_suffix_now_shares_and_chunks.)"""
    cfg, model, params = stack
    rng = jax.random.key(29)
    rng, k = jax.random.split(rng)
    base = jax.random.randint(k, (18,), 2, cfg.vocab_size).tolist()
    rng, k = jax.random.split(rng)
    tail = jax.random.randint(k, (30,), 2, cfg.vocab_size).tolist()
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=8, prefix_sharing=True,
                        prefill_chunk=0)
    eng.add_requests([Request(rid=0, prompt=list(base), max_new_tokens=20)])
    long_sfx = Request(rid=1, prompt=base[:16] + tail, max_new_tokens=2)
    # suffix (30) > max(block_size, matched 16): full plain cost, and
    # admission prefills rather than queueing 30 catch-up steps
    assert eng.blocks_needed(long_sfx) == eng.pool.blocks_for(46)
    assert eng.add_requests([long_sfx]) == 1
    assert eng.metrics["shared_admissions"] == 0
    assert eng.slot_pending[1] == []


def test_cow_park_diverts_scatter_off_shared_block(stack):
    """THE corruption regression: a slot parked because copy-on-write
    could not allocate must not let its ride-along scatter land in the
    still-shared block — the co-holder's stream would silently change.
    Here slots A and C grab the last free blocks in the same step that
    B needs its CoW, so B parks while sharing A's tail block; every
    stream must still equal its uncontended solo run."""
    cfg, model, params = stack
    rng = jax.random.key(23)
    rng, k = jax.random.split(rng)
    pa = jax.random.randint(k, (8,), 2, cfg.vocab_size).tolist()
    rng, k = jax.random.split(rng)
    pc = jax.random.randint(k, (4,), 2, cfg.vocab_size).tolist()
    rng, k = jax.random.split(rng)
    # B shares A's first block + one token of A's second (tail) block,
    # but B's next prompt token DIFFERS from A's token there — exactly
    # the write that corrupts A if it lands in the shared block
    pb = pa[:5] + [int(jax.random.randint(k, (), 2, cfg.vocab_size))]
    assert pb[5] != pa[5]
    a = Request(rid=0, prompt=list(pa), max_new_tokens=4)
    c = Request(rid=1, prompt=list(pc), max_new_tokens=4)
    b = Request(rid=2, prompt=list(pb), max_new_tokens=3)
    eng = ServingEngine(model, params, batch_size=3, max_seq=64,
                        paged=True, block_size=4, num_blocks=6,
                        prefix_sharing=True)
    assert eng.add_requests([a]) == 1        # slot 0: blocks x, y
    assert eng.add_requests([c]) == 1        # slot 1: block c1
    assert eng.add_requests([b]) == 1        # slot 2: shares x + tail y
    assert eng.metrics["shared_admissions"] == 1
    done = eng.run([])
    assert len(done) == 3
    assert eng.metrics["cow_parks"] >= 1     # the dangerous state was hit
    assert eng.pool.available == eng.pool.total
    eng.pool.check()
    for r in (a, c, b):
        solo = ServingEngine(model, params, batch_size=1, max_seq=64,
                             paged=True, block_size=4, prefix_sharing=False)
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens)])
        assert d.out_tokens == r.out_tokens, r.rid


def test_paged_kernel_engine_streams_match_gather_engine(stack):
    """use_kernel=True (Pallas paged-attention decode, interpret mode on
    CPU) serves the same token streams as the jnp gather path."""
    cfg, model, params = stack
    lens = [5, 11, 7]
    a, b = _reqs(cfg, lens, max_new=4), _reqs(cfg, lens, max_new=4)
    gather = ServingEngine(model, params, batch_size=3, max_seq=32,
                           paged=True, block_size=8, use_kernel=False)
    kernel = ServingEngine(model, params, batch_size=3, max_seq=32,
                           paged=True, block_size=8, use_kernel=True)
    gather.run(list(a))
    kernel.run(list(b))
    for x, y in zip(a, b):
        assert x.out_tokens == y.out_tokens, x.rid


def test_moe_engine_never_shares_prefixes(stack):
    """MoE catch-up decode would co-batch through shared expert capacity
    (the documented bit-exactness caveat), so sharing stays off."""
    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        block_size=8, prefix_sharing=True)
    assert eng.paged and not eng.prefix_sharing


def test_paged_admission_counts_only_callers_requests(stack):
    """add_requests returns how many of the CALLER's requests were taken
    even when preempted requests re-admit first."""
    cfg, model, params = stack
    eng = ServingEngine(model, params, batch_size=2, max_seq=64,
                        paged=True, block_size=4, num_blocks=4)
    first = _reqs(cfg, [4, 4], max_new=8)
    eng.add_requests(list(first))
    while eng.metrics["preemptions"] == 0 and eng.active:
        eng.step()                           # run until the stall evicts one
    assert eng.waiting == 1
    late = _reqs(cfg, [4], max_new=2, seed=9)
    # pool is stalled: the preempted request resumes first; the caller's
    # request is only counted when IT is admitted
    n = eng.add_requests(list(late))
    assert n in (0, 1)
    done = eng.run(late[n:])
    assert eng.metrics["completed"] == 3 or len(done) >= 1
