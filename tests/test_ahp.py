"""AHP: reproduction of the paper's Tables 3-5 + algebraic properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import ahp


# ------------------------------------------------------ paper reproduction
def test_reproduces_paper_table3_hello_world_exactly():
    res = ahp.reproduce_paper_tables()["Hello World"]
    got = dict(zip(res.alternatives, res.scores))
    assert got["Falcon"] == pytest.approx(0.505, abs=0.002)
    assert got["FastApi"] == pytest.approx(0.317, abs=0.002)
    assert got["Flask"] == pytest.approx(0.178, abs=0.002)


def test_reproduces_paper_table4_fibonacci():
    # paper's Table 2 inputs are rounded to integers -> 1pp tolerance
    res = ahp.reproduce_paper_tables()["Finding value of Fibonacci"]
    got = dict(zip(res.alternatives, res.scores))
    for name, want in ahp.PAPER_RESULTS["Finding value of Fibonacci"].items():
        assert got[name] == pytest.approx(want, abs=0.01)


def test_reproduces_paper_table5_file_retrieval_ranking():
    res = ahp.reproduce_paper_tables()["File retrival from database"]
    got = dict(zip(res.alternatives, res.scores))
    for name, want in ahp.PAPER_RESULTS["File retrival from database"].items():
        assert got[name] == pytest.approx(want, abs=0.005)
    # paper's headline: Falcon wins every scenario
    assert max(got, key=got.get) == "Falcon"


def test_falcon_wins_all_scenarios():
    for scenario, res in ahp.reproduce_paper_tables().items():
        assert res.ranking()[0][0] == "Falcon", scenario


def test_criteria_weights_equal_when_unpreferred():
    res = ahp.reproduce_paper_tables()["Hello World"]
    np.testing.assert_allclose(res.criteria_weights, 1 / 6, atol=1e-9)


# ------------------------------------------------------------- properties
@st.composite
def measurements(draw, n_alts=3, n_crit=3):
    vals = draw(st.lists(
        st.lists(st.floats(min_value=0.1, max_value=1e4,
                           allow_nan=False, allow_infinity=False),
                 min_size=n_alts, max_size=n_alts),
        min_size=n_crit, max_size=n_crit))
    return np.array(vals)


@settings(max_examples=30, deadline=None)
@given(measurements())
def test_scores_are_a_distribution(vals):
    crit = [ahp.Criterion(f"c{i}", higher_is_better=bool(i % 2))
            for i in range(vals.shape[0])]
    alts = [f"a{i}" for i in range(vals.shape[1])]
    res = ahp.run_ahp(alts, crit, vals)
    assert np.all(res.scores >= -1e-12)
    assert np.isclose(res.scores.sum(), 1.0, atol=1e-9)


@settings(max_examples=30, deadline=None)
@given(measurements(), st.floats(min_value=0.5, max_value=100.0))
def test_scale_invariance(vals, scale):
    """Ratio-based preferences are invariant to rescaling a criterion
    (until the 1/9..9 clamp binds identically)."""
    crit = [ahp.Criterion(f"c{i}") for i in range(vals.shape[0])]
    alts = [f"a{i}" for i in range(vals.shape[1])]
    r1 = ahp.run_ahp(alts, crit, vals)
    r2 = ahp.run_ahp(alts, crit, vals * scale)
    np.testing.assert_allclose(r1.scores, r2.scores, atol=1e-9)


@settings(max_examples=30, deadline=None)
@given(measurements())
def test_permutation_equivariance(vals):
    crit = [ahp.Criterion(f"c{i}") for i in range(vals.shape[0])]
    alts = ["a0", "a1", "a2"]
    perm = [2, 0, 1]
    r1 = ahp.run_ahp(alts, crit, vals)
    r2 = ahp.run_ahp([alts[p] for p in perm], crit, vals[:, perm])
    np.testing.assert_allclose(r1.scores[perm], r2.scores, atol=1e-9)


def test_dominant_alternative_wins():
    vals = np.array([[10.0, 1.0, 1.0], [20.0, 2.0, 1.0]])
    crit = [ahp.Criterion("t", higher_is_better=True),
            ahp.Criterion("u", higher_is_better=True)]
    res = ahp.run_ahp(["best", "mid", "worst"], crit, vals)
    assert res.ranking()[0][0] == "best"
    assert res.ranking()[-1][0] == "worst"


def test_consistency_ratio_of_consistent_matrix_is_zero():
    m = ahp.pairwise_matrix([1.0, 2.0, 4.0], ahp.higher_is_better)
    assert ahp.consistency_ratio(m) < 1e-6
