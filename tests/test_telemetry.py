"""Telemetry: tracer determinism, trace/stats reconstruction, metrics
registry + Prometheus exposition, and bit-identity with tracing on.

The load-bearing claims: (1) a scripted workload under a VirtualClock
emits **byte-identical** trace JSON run to run, (2) the trace's queued
span and TTFT are the *same numbers* the scheduler/engine report (same
clock reads, not a re-measurement), and (3) turning tracing on changes
no token stream anywhere on the engine grid.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.async_loop import AsyncServeLoop
from repro.serve.clock import VirtualClock
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import Scheduler
from repro.serve.telemetry import (NOOP, PID_LOOP, PID_POOL, PID_REQUESTS,
                                   Counter, Gauge, Histogram,
                                   MetricsRegistry, NoopTracer, Tracer,
                                   prometheus_text)

MAX_SEQ = 64


# ===================================================== tracer unit tests
def test_ring_buffer_bounds_and_counts_drops():
    vc = VirtualClock()
    tr = Tracer(clock=vc, capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    names = [e["name"] for e in tr.chrome_trace()["traceEvents"]
             if e["ph"] == "i"]
    assert names == ["e6", "e7", "e8", "e9"]     # oldest evicted first
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 6


def test_capacity_must_be_positive():
    with pytest.raises(ValueError, match="capacity"):
        Tracer(capacity=0)


def test_noop_is_default_and_inert(tmp_path):
    assert NOOP.enabled is False
    NOOP.instant("x")
    NOOP.complete("x", 0.0, 1.0)
    NOOP.counter("x", {"v": 1})
    with NOOP.span("x"):
        pass
    assert NOOP.chrome_trace()["traceEvents"] == []
    with pytest.raises(RuntimeError, match="no-op tracer"):
        NOOP.write_chrome_trace(tmp_path / "t.json")


def test_span_context_manager_measures_clock():
    vc = VirtualClock()
    tr = Tracer(clock=vc)
    with tr.span("work", pid=PID_LOOP, args={"k": 1}):
        vc.advance(0.5)
    (ev,) = [e for e in tr.chrome_trace()["traceEvents"]
             if e["ph"] == "X"]
    assert ev["name"] == "work"
    assert ev["ts"] == 0.0 and ev["dur"] == 500000.0
    assert ev["args"] == {"k": 1}


def test_negative_duration_clamped():
    tr = Tracer(clock=VirtualClock())
    tr.complete("x", 1.0, -0.5)
    (ev,) = [e for e in tr.chrome_trace()["traceEvents"]
             if e["ph"] == "X"]
    assert ev["dur"] == 0.0


# =================================================== registry unit tests
def test_counter_monotonic():
    c = Counter("hits")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)


def test_gauge_moves_both_ways():
    g = Gauge("depth")
    g.set(5)
    g.inc()
    g.dec(2)
    assert g.value == 4


def test_histogram_cumulative_buckets():
    h = Histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v)
    samples = dict(h.samples())
    assert samples['_bucket{le="0.1"}'] == 1
    assert samples['_bucket{le="1.0"}'] == 3
    assert samples['_bucket{le="+Inf"}'] == 4
    assert samples["_count"] == 4
    assert samples["_sum"] == pytest.approx(6.05)


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("ticks")
    assert reg.counter("ticks") is reg.counter("ticks")   # create-or-get
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("ticks")


def test_registry_source_polls_and_skips_non_numeric():
    state = {"completed": 1, "label": "text", "flag": True, "ratio": 0.5}
    reg = MetricsRegistry(labels={"replica": "lm/0"})
    reg.source("engine", lambda: state)
    names = {name for name, *_ in reg.collect()}
    assert "engine_completed" in names and "engine_ratio" in names
    assert "engine_label" not in names     # non-numeric skipped
    assert "engine_flag" not in names      # bools are not metrics
    state["completed"] = 7                 # polled, not copied
    text = reg.prometheus_text()
    assert 'engine_completed{replica="lm/0"} 7' in text


def test_prometheus_merge_across_registries():
    regs = []
    for i in range(2):
        reg = MetricsRegistry(labels={"replica": f"lm/{i}"})
        reg.counter("served", help="requests served").inc(i + 1)
        h = reg.histogram("wait", buckets=(1.0,))
        h.observe(0.5)
        regs.append(reg)
    text = prometheus_text(regs)
    # HELP/TYPE once per name, samples from both registries under it
    assert text.count("# TYPE served counter") == 1
    assert text.count("# HELP served requests served") == 1
    assert 'served{replica="lm/0"} 1' in text
    assert 'served{replica="lm/1"} 2' in text
    # registry labels fold into the histogram's own le label
    assert 'wait_bucket{replica="lm/0",le="1.0"} 1' in text
    assert 'wait_count{replica="lm/1"} 1' in text


def test_metric_names_sanitized():
    reg = MetricsRegistry()
    reg.source("serving", lambda: {"open_loop.ttft/p50": 3})
    text = reg.prometheus_text()
    assert "serving_open_loop_ttft_p50 3" in text


# ================================================== engine integration
@pytest.fixture(scope="module")
def stack():
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompts(cfg, lens, seed=1):
    rng = jax.random.key(seed)
    out = []
    for L in lens:
        rng, k = jax.random.split(rng)
        out.append(jax.random.randint(k, (L,), 2, cfg.vocab_size).tolist())
    return out


def _scripted_serve(model, params, prompts, **kw):
    """One deterministic serve: all requests submitted at t=0, the loop
    pumped on a virtual 10 ms tick with the tracer on the same clock.
    Returns (tracer, scheduler, requests)."""
    vc = VirtualClock()
    tracer = Tracer(clock=vc)
    eng = ServingEngine(model, params, batch_size=4, max_seq=MAX_SEQ,
                        clock=vc, tracer=tracer, **kw)
    sched = Scheduler(eng, clock=vc)
    loop = AsyncServeLoop(sched)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=4)
            for i, p in enumerate(prompts)]
    handles = []
    for r in reqs:
        r.submitted_s = vc()            # scheduler timeline, not wall
        handles.append(loop.submit(r))
    t = 0
    while not all(h.done for h in handles):
        loop.run_once()
        vc.advance(0.01)
        t += 1
        assert t < 500, "serve did not converge"
    return tracer, sched, reqs


def test_trace_byte_identical_under_virtual_clock(stack, tmp_path):
    """Acceptance: two runs of the same scripted workload emit
    byte-identical trace JSON."""
    cfg, model, params = stack
    lens = [5, 9, 7, 12, 6]
    paths = []
    for run in range(2):
        tracer, _, _ = _scripted_serve(model, params,
                                       _prompts(cfg, lens, seed=2))
        p = tmp_path / f"run{run}.json"
        tracer.write_chrome_trace(p)
        paths.append(p)
    assert paths[0].read_bytes() == paths[1].read_bytes()


def test_trace_validates_and_covers_all_tracks(stack, tmp_path):
    cfg, model, params = stack
    tracer, _, reqs = _scripted_serve(model, params,
                                      _prompts(cfg, [5, 9, 7], seed=3))
    p = tmp_path / "t.json"
    tracer.write_chrome_trace(p)
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]
                           / "scripts"))
    try:
        from check_trace import validate
    finally:
        sys.path.pop(0)
    assert validate(p) == []
    events = json.loads(p.read_text())["traceEvents"]
    pids = {e["pid"] for e in events}
    assert {PID_LOOP, PID_REQUESTS, PID_POOL} <= pids
    names = {e["name"] for e in events}
    assert {"submit", "queued", "admitted", "first_token", "request",
            "prefill", "decode", "plan-window", "commit-wait",
            "pool"} <= names
    # one lifecycle span per request, every one completed
    lifecycle = [e for e in events
                 if e["name"] == "request" and e["ph"] == "X"]
    assert sorted(e["tid"] for e in lifecycle) \
        == sorted(r.rid for r in reqs)
    assert all(e["args"]["status"] == "completed" for e in lifecycle)


def test_trace_reconstructs_ttft_and_queue_wait(stack):
    """Acceptance: per-request spans reconstruct TTFT and queue wait
    equal to the engine's/scheduler's own reported values."""
    cfg, model, params = stack
    tracer, sched, reqs = _scripted_serve(
        model, params, _prompts(cfg, [5, 9, 7, 12, 6], seed=4))
    events = tracer.chrome_trace()["traceEvents"]

    def us(x):
        return round(x * 1e6, 1)

    # queued spans carry the exact same durations the stats recorded
    queued = sorted(e["dur"] for e in events if e["name"] == "queued")
    assert queued == sorted(us(w) for w in sched.stats.queue_wait_s)

    by_rid = {}
    for e in events:
        if e["name"] in ("submit", "first_token", "request"):
            by_rid.setdefault(e["tid"], {})[e["name"]] = e
    for r in reqs:
        ev = by_rid[r.rid]
        # TTFT from the trace == TTFT from the engine's stamps
        assert ev["first_token"]["ts"] - ev["submit"]["ts"] \
            == pytest.approx(us(r.first_token_s - r.submitted_s))
        # lifecycle span == the request's reported latency
        assert ev["request"]["dur"] == pytest.approx(us(r.latency_s))
        assert ev["request"]["args"]["tokens"] == len(r.out_tokens)


def test_tick_phases_cover_the_pipeline(stack):
    cfg, model, params = stack
    tracer, sched, _ = _scripted_serve(model, params,
                                       _prompts(cfg, [5, 7], seed=5))
    loop_spans = [e for e in tracer.chrome_trace()["traceEvents"]
                  if e["pid"] == PID_LOOP and e["ph"] == "X"]
    phases = {e["name"] for e in loop_spans}
    assert {"apply-cancels", "fill", "dispatch", "plan-window",
            "commit-wait", "emit"} <= phases
    # committed ticks all carry the full dispatch->commit split
    n_commit = sum(1 for e in loop_spans if e["name"] == "commit-wait")
    assert n_commit == sched.stats.ticks
    for e in loop_spans:
        assert e["dur"] >= 0.0


def test_pool_track_alloc_free_and_occupancy(stack):
    cfg, model, params = stack
    tracer, _, _ = _scripted_serve(model, params,
                                   _prompts(cfg, [5, 9, 7], seed=6))
    events = tracer.chrome_trace()["traceEvents"]
    pool = [e for e in events if e["pid"] == PID_POOL]
    assert any(e["name"] == "alloc" for e in pool)
    assert any(e["name"] == "free" for e in pool)
    counters = [e for e in pool if e["ph"] == "C" and e["name"] == "pool"]
    assert counters
    assert all(set(e["args"]) == {"used", "shared", "cached"}
               for e in counters)
    # everything retired: the last occupancy sample (emitted on the
    # final free) shows no held blocks
    assert counters[-1]["args"]["used"] == 0


# ------------------------------------------ tracing-on bit-identity grid
GRID = {
    "paged": ({}, [5, 9, 7, 12, 6]),
    "kernel": ({"use_kernel": True}, [5, 9, 7, 12, 6]),
    "shared_prefix": ({}, None),
    "chunked": ({"prefill_chunk": 8}, [21, 30, 17, 26, 19]),
    "speculative": ("SPEC", [5, 9, 7, 12, 6]),
}


@pytest.mark.parametrize("config", list(GRID))
def test_streams_bit_identical_with_tracing_enabled(stack, config):
    """Acceptance: async streams stay bit-identical to the sync drain
    with tracing ENABLED, across the engine grid — observation must not
    perturb the system."""
    cfg, model, params = stack
    kw, lens = GRID[config]
    if kw == "SPEC":
        kw = {"draft_model": model, "draft_params": params,
              "speculation": 3}
    if config == "shared_prefix":
        stem = _prompts(cfg, [20], seed=7)[0]
        tails = _prompts(cfg, [3, 5, 2], seed=8)
        prompts = [list(stem)] + [stem + tl for tl in tails]
    else:
        prompts = _prompts(cfg, lens, seed=9)

    vc = VirtualClock()
    tracer = Tracer(clock=vc)
    eng = ServingEngine(model, params, batch_size=4, max_seq=MAX_SEQ,
                        clock=vc, tracer=tracer, **kw)
    loop = AsyncServeLoop(Scheduler(eng, clock=vc))
    streams = {i: [] for i in range(len(prompts))}
    handles = {}
    t = 0
    while len(handles) < len(prompts) \
            or not all(h.done for h in handles.values()):
        # arrivals staggered 2 ticks apart: mid-decode admissions
        for i, p in enumerate(prompts):
            if i not in handles and 2 * i <= t:
                handles[i] = loop.submit(
                    Request(rid=i, prompt=list(p), max_new_tokens=4),
                    lambda tok, lp, rid=i: streams[rid].append(tok))
        loop.run_once()
        vc.advance(0.01)
        t += 1
        assert t < 500, "serve did not converge"
    assert len(tracer) > 0              # tracing actually recorded

    ref = ServingEngine(model, params, batch_size=4, max_seq=MAX_SEQ,
                        **kw)           # untraced synchronous reference
    ref_done = ref.run([Request(rid=100 + i, prompt=list(p),
                                max_new_tokens=4)
                        for i, p in enumerate(prompts)])
    assert streams == {r.rid - 100: r.out_tokens for r in ref_done}
    if config == "speculative":
        spec = [e for e in tracer.chrome_trace()["traceEvents"]
                if e["name"] == "speculation"]
        assert spec, "speculative serve emitted no window counters"
        assert all(0 <= e["args"]["accepted"] <= e["args"]["proposed"]
                   for e in spec)


# ---------------------------------------------- kernel dispatch counters
def test_kernel_dispatch_counters_reach_prometheus(stack):
    """`kernel_windows` counts fused multi-token launches (verify +
    chunk ticks) and `kernel_positions` the total real query positions
    through the paged kernel — so a Prometheus scrape tells fused-window
    launches from single-token decode launches. Gather-path engines
    must leave both at zero."""
    cfg, model, params = stack
    prompts = _prompts(cfg, [20, 9], seed=21)
    eng = ServingEngine(model, params, batch_size=2, max_seq=MAX_SEQ,
                        block_size=8, use_kernel=True, prefill_chunk=8)
    eng.run([Request(rid=i, prompt=list(p), max_new_tokens=4)
             for i, p in enumerate(prompts)])
    # chunk ticks ran fused windows; decode ticks added 1 position per
    # active row with no window launch
    assert eng.metrics["kernel_windows"] > 0
    assert eng.metrics["chunk_steps"] >= eng.metrics["kernel_windows"]
    assert eng.metrics["kernel_positions"] > eng.metrics["kernel_windows"]
    reg = MetricsRegistry(labels={"replica": "lm/0"})
    reg.source("engine", lambda: eng.metrics)
    text = reg.prometheus_text()
    assert 'engine_kernel_windows{replica="lm/0"}' in text
    assert 'engine_kernel_positions{replica="lm/0"}' in text
    gather = ServingEngine(model, params, batch_size=2, max_seq=MAX_SEQ,
                           block_size=8, use_kernel=False, prefill_chunk=8)
    gather.run([Request(rid=10 + i, prompt=list(p), max_new_tokens=4)
                for i, p in enumerate(prompts)])
    assert gather.metrics["kernel_windows"] == 0
    assert gather.metrics["kernel_positions"] == 0


# ------------------------------------------------- service-level scrape
def test_service_and_supervisor_prometheus_exposition(stack):
    from repro.core.supervisor import Supervisor
    from repro.serve.service import (make_lm_service,
                                     service_prometheus_text)
    cfg, model, params = stack
    sup = Supervisor()
    svc = make_lm_service("lm", model, params, n_replicas=1,
                          batch_size=2, max_seq=MAX_SEQ, supervisor=sup)
    sup.start_all()
    prompt = _prompts(cfg, [5], seed=10)[0]
    out = svc.balancer({"prompt": prompt, "max_new_tokens": 3})
    assert len(out["tokens"]) == 3
    text = service_prometheus_text(svc)
    assert 'engine_completed{replica="lm/0"} 1' in text
    assert 'scheduler_completed{replica="lm/0"} 1' in text
    assert 'balancer_served{service="lm"} 1' in text
    assert "# TYPE engine_completed gauge" in text
    # fleet-level scrape: replica + balancer + supervisor accounting
    fleet = sup.prometheus_text()
    assert 'engine_completed{replica="lm/0"} 1' in fleet
    assert 'supervisor_up{service="lm"} 1' in fleet
    assert 'supervisor_restart_attempts{service="lm"} 0' in fleet
