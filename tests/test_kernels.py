"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Deliverable c: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention,
                                                sharded_decode_attention)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rwkv_scan.ops import wkv
from repro.kernels.rwkv_scan.ref import wkv_ref

RNG = jax.random.PRNGKey(0)


def tol(dt):
    return 3e-2 if dt == jnp.bfloat16 else 3e-5


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize("B,Hq,Hkv,S,T,hd,win,dt", [
    (2, 4, 2, 256, 256, 64, 0, jnp.float32),
    (1, 4, 4, 128, 384, 64, 0, jnp.bfloat16),     # MHA, q shorter than kv
    (2, 8, 2, 256, 256, 128, 128, jnp.float32),   # sliding window
    (1, 2, 1, 512, 512, 192, 0, jnp.float32),     # nemotron head_dim
    (1, 6, 6, 128, 128, 64, 0, jnp.bfloat16),     # whisper-ish
])
def test_flash_attention_matches_ref(B, Hq, Hkv, S, T, hd, win, dt):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd), dt)
    k = jax.random.normal(ks[1], (B, Hkv, T, hd), dt)
    v = jax.random.normal(ks[2], (B, Hkv, T, hd), dt)
    out = flash_attention(q, k, v, sliding_window=win)
    ref = flash_attention_ref(q, k, v, sliding_window=win)
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               atol=tol(dt), rtol=tol(dt))


def test_flash_attention_non_square_blocks():
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention(q, k, v, bq=64, bk=128)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.float32(out), np.float32(ref), atol=3e-5,
                               rtol=3e-5)


# ------------------------------------------------------------------ decode
@pytest.mark.parametrize("B,Hq,Hkv,T,hd,nv,win,dt", [
    (2, 8, 2, 512, 64, 300, 0, jnp.float32),
    (1, 4, 1, 1024, 128, 1000, 256, jnp.bfloat16),
    (2, 4, 4, 512, 64, 512, 0, jnp.float32),
    (1, 8, 8, 256, 112, 100, 0, jnp.float32),     # kimi head_dim
])
def test_decode_attention_matches_ref(B, Hq, Hkv, T, hd, nv, win, dt):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), dt)
    k = jax.random.normal(ks[1], (B, Hkv, T, hd), dt)
    v = jax.random.normal(ks[2], (B, Hkv, T, hd), dt)
    out, lse = decode_attention(q, k, v, nv, sliding_window=win)
    ro, rl = decode_attention_ref(q, k, v, nv, sliding_window=win)
    np.testing.assert_allclose(np.float32(out), np.float32(ro),
                               atol=tol(dt), rtol=tol(dt))
    np.testing.assert_allclose(np.float32(lse), np.float32(rl),
                               atol=tol(dt), rtol=tol(dt))


@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_decode_lse_combine(n_shards):
    """Flash-decoding invariant: sequence-sharded partials + LSE merge ==
    unsharded attention."""
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, 4, 2 * 64)).reshape(2, 4, 128)
    k = jax.random.normal(ks[1], (2, 2, 512, 128))
    v = jax.random.normal(ks[2], (2, 2, 512, 128))
    ro, _ = decode_attention_ref(q, k, v, 400)
    so = sharded_decode_attention(q, jnp.split(k, n_shards, 2),
                                  jnp.split(v, n_shards, 2), 400)
    np.testing.assert_allclose(np.float32(so), np.float32(ro), atol=3e-5,
                               rtol=3e-5)


# --------------------------------------------------------------------- wkv
@pytest.mark.parametrize("B,T,H,hd,bt", [
    (2, 128, 2, 64, 64),
    (1, 96, 4, 32, 32),
    (1, 64, 1, 64, 16),
])
def test_wkv_scan_matches_ref(B, T, H, hd, bt):
    ks = jax.random.split(RNG, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    out, sT = wkv(r, k, v, w, u, s0, bt=bt)
    ro, rs = wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.float32(out), np.float32(ro), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.float32(sT), np.float32(rs), atol=2e-4,
                               rtol=2e-4)


def test_wkv_state_carry_equals_two_halves():
    """Running T then T (carrying state) == running 2T at once."""
    ks = jax.random.split(RNG, 5)
    B, T, H, hd = 1, 64, 2, 32
    r = jax.random.normal(ks[0], (B, 2 * T, H, hd))
    k = jax.random.normal(ks[1], (B, 2 * T, H, hd))
    v = jax.random.normal(ks[2], (B, 2 * T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, 2 * T, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    o_full, s_full = wkv(r, k, v, w, u, s0, bt=32)
    o1, s1 = wkv(r[:, :T], k[:, :T], v[:, :T], w[:, :T], u, s0, bt=32)
    o2, s2 = wkv(r[:, T:], k[:, T:], v[:, T:], w[:, T:], u, s1, bt=32)
    np.testing.assert_allclose(np.float32(jnp.concatenate([o1, o2], 1)),
                               np.float32(o_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.float32(s2), np.float32(s_full),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------- ssm scan
from repro.kernels.ssm_scan.ops import selective_scan as pallas_ssm  # noqa: E402
from repro.kernels.ssm_scan.ref import ssm_scan_ref  # noqa: E402


def _ssm_inputs(key, B, T, di, N, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    u = jax.random.normal(ks[0], (B, T, di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, di), dtype)) * 0.1
    Bm = jax.random.normal(ks[2], (B, T, N), dtype)
    Cm = jax.random.normal(ks[3], (B, T, N), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N), jnp.float32) * 0.3)
    D = jnp.ones((di,), jnp.float32)
    s0 = jnp.zeros((B, di, N), jnp.float32)
    return u, dt, Bm, Cm, A, D, s0


@pytest.mark.parametrize("B,T,di,N,bt", [
    (2, 128, 64, 16, 64),
    (1, 96, 128, 16, 32),
    (1, 64, 32, 8, 16),
])
def test_ssm_scan_matches_ref(B, T, di, N, bt):
    args = _ssm_inputs(RNG, B, T, di, N)
    y, sT = pallas_ssm(*args, bt=bt)
    ry, rs = ssm_scan_ref(*args)
    np.testing.assert_allclose(np.float32(y), np.float32(ry), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.float32(sT), np.float32(rs), atol=2e-4,
                               rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_dtypes(dtype):
    args = _ssm_inputs(RNG, 1, 64, 32, 16, dtype)
    y, sT = pallas_ssm(*args, bt=32)
    ry, rs = ssm_scan_ref(*[a.astype(jnp.float32)
                            if a.dtype == jnp.bfloat16 else a for a in args])
    atol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.float32(y), np.float32(ry), atol=atol,
                               rtol=atol)


def test_ssm_scan_state_carry_equals_two_halves():
    B, T, di, N = 1, 64, 32, 16
    u, dt, Bm, Cm, A, D, s0 = _ssm_inputs(RNG, B, 2 * T, di, N)
    yf, sf = pallas_ssm(u, dt, Bm, Cm, A, D, s0, bt=32)
    y1, s1 = pallas_ssm(u[:, :T], dt[:, :T], Bm[:, :T], Cm[:, :T], A, D,
                        s0, bt=32)
    y2, s2 = pallas_ssm(u[:, T:], dt[:, T:], Bm[:, T:], Cm[:, T:], A, D,
                        s1, bt=32)
    np.testing.assert_allclose(np.float32(jnp.concatenate([y1, y2], 1)),
                               np.float32(yf), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.float32(s2), np.float32(sf), atol=1e-4,
                               rtol=1e-4)


def test_ssm_scan_matches_model_block():
    """The kernel agrees with repro.models.ssm.selective_scan — the
    hymba model path it replaces on TPU."""
    from repro.models import ssm as model_ssm
    args = _ssm_inputs(RNG, 2, 64, 32, 16)
    y, sT = pallas_ssm(*args, bt=32)
    my, ms = model_ssm.selective_scan(*args)
    np.testing.assert_allclose(np.float32(y), np.float32(my), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.float32(sT), np.float32(ms), atol=2e-4,
                               rtol=2e-4)
