"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Deliverable c: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention,
                                                sharded_decode_attention)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rwkv_scan.ops import wkv
from repro.kernels.rwkv_scan.ref import wkv_ref

RNG = jax.random.PRNGKey(0)


def tol(dt):
    return 3e-2 if dt == jnp.bfloat16 else 3e-5


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize("B,Hq,Hkv,S,T,hd,win,dt", [
    (2, 4, 2, 256, 256, 64, 0, jnp.float32),
    (1, 4, 4, 128, 384, 64, 0, jnp.bfloat16),     # MHA, q shorter than kv
    (2, 8, 2, 256, 256, 128, 128, jnp.float32),   # sliding window
    (1, 2, 1, 512, 512, 192, 0, jnp.float32),     # nemotron head_dim
    (1, 6, 6, 128, 128, 64, 0, jnp.bfloat16),     # whisper-ish
])
def test_flash_attention_matches_ref(B, Hq, Hkv, S, T, hd, win, dt):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd), dt)
    k = jax.random.normal(ks[1], (B, Hkv, T, hd), dt)
    v = jax.random.normal(ks[2], (B, Hkv, T, hd), dt)
    out = flash_attention(q, k, v, sliding_window=win)
    ref = flash_attention_ref(q, k, v, sliding_window=win)
    np.testing.assert_allclose(np.float32(out), np.float32(ref),
                               atol=tol(dt), rtol=tol(dt))


def test_flash_attention_non_square_blocks():
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention(q, k, v, bq=64, bk=128)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.float32(out), np.float32(ref), atol=3e-5,
                               rtol=3e-5)


# ------------------------------------------------------------------ decode
@pytest.mark.parametrize("B,Hq,Hkv,T,hd,nv,win,dt", [
    (2, 8, 2, 512, 64, 300, 0, jnp.float32),
    (1, 4, 1, 1024, 128, 1000, 256, jnp.bfloat16),
    (2, 4, 4, 512, 64, 512, 0, jnp.float32),
    (1, 8, 8, 256, 112, 100, 0, jnp.float32),     # kimi head_dim
])
def test_decode_attention_matches_ref(B, Hq, Hkv, T, hd, nv, win, dt):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), dt)
    k = jax.random.normal(ks[1], (B, Hkv, T, hd), dt)
    v = jax.random.normal(ks[2], (B, Hkv, T, hd), dt)
    out, lse = decode_attention(q, k, v, nv, sliding_window=win)
    ro, rl = decode_attention_ref(q, k, v, nv, sliding_window=win)
    np.testing.assert_allclose(np.float32(out), np.float32(ro),
                               atol=tol(dt), rtol=tol(dt))
    np.testing.assert_allclose(np.float32(lse), np.float32(rl),
                               atol=tol(dt), rtol=tol(dt))


@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_decode_lse_combine(n_shards):
    """Flash-decoding invariant: sequence-sharded partials + LSE merge ==
    unsharded attention."""
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, 4, 2 * 64)).reshape(2, 4, 128)
    k = jax.random.normal(ks[1], (2, 2, 512, 128))
    v = jax.random.normal(ks[2], (2, 2, 512, 128))
    ro, _ = decode_attention_ref(q, k, v, 400)
    so = sharded_decode_attention(q, jnp.split(k, n_shards, 2),
                                  jnp.split(v, n_shards, 2), 400)
    np.testing.assert_allclose(np.float32(so), np.float32(ro), atol=3e-5,
                               rtol=3e-5)


# --------------------------------------------------------------------- wkv
@pytest.mark.parametrize("B,T,H,hd,bt", [
    (2, 128, 2, 64, 64),
    (1, 96, 4, 32, 32),
    (1, 64, 1, 64, 16),
])
def test_wkv_scan_matches_ref(B, T, H, hd, bt):
    ks = jax.random.split(RNG, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    out, sT = wkv(r, k, v, w, u, s0, bt=bt)
    ro, rs = wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.float32(out), np.float32(ro), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.float32(sT), np.float32(rs), atol=2e-4,
                               rtol=2e-4)


def test_wkv_state_carry_equals_two_halves():
    """Running T then T (carrying state) == running 2T at once."""
    ks = jax.random.split(RNG, 5)
    B, T, H, hd = 1, 64, 2, 32
    r = jax.random.normal(ks[0], (B, 2 * T, H, hd))
    k = jax.random.normal(ks[1], (B, 2 * T, H, hd))
    v = jax.random.normal(ks[2], (B, 2 * T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, 2 * T, H, hd))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd))
    o_full, s_full = wkv(r, k, v, w, u, s0, bt=32)
    o1, s1 = wkv(r[:, :T], k[:, :T], v[:, :T], w[:, :T], u, s0, bt=32)
    o2, s2 = wkv(r[:, T:], k[:, T:], v[:, T:], w[:, T:], u, s1, bt=32)
    np.testing.assert_allclose(np.float32(jnp.concatenate([o1, o2], 1)),
                               np.float32(o_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.float32(s2), np.float32(s_full),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------- paged decode attention
from repro.kernels.paged_attention.ops import (  # noqa: E402
    paged_decode_attention as paged_decode)
from repro.kernels.paged_attention.ref import (  # noqa: E402
    gathered_decode_ref, paged_decode_attention_ref)


def _paged_case(B, Hq, Hkv, hd, bs, max_blocks, dt, *, seed=0, full=False):
    """A pool + per-row disjoint block tables at ragged lengths, the
    shapes the serving engine hands the kernel: zeroed table tails point
    at the scratch block, row lengths land anywhere in [1, capacity]."""
    nb = B * max_blocks + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, hd), dt)
    pool_k = jax.random.normal(ks[1], (nb, bs, Hkv, hd), dt)
    pool_v = jax.random.normal(ks[2], (nb, bs, Hkv, hd), dt)
    rng = np.random.default_rng(seed + B * 1000 + hd)
    free = list(rng.permutation(np.arange(1, nb)))
    lens = np.zeros(B, np.int32)
    table = np.zeros((B, max_blocks), np.int32)
    for b in range(B):
        lens[b] = max_blocks * bs if full \
            else int(rng.integers(1, max_blocks * bs + 1))
        for i in range(-(-int(lens[b]) // bs)):
            table[b, i] = free.pop()
    return q, pool_k, pool_v, jnp.asarray(table), jnp.asarray(lens)


# num_heads x head_dim x block_size x active-slot count x window x dtype;
# every row also varies ragged per-row lengths via _paged_case
PAGED_GRID = [
    (1, 4, 1, 64, 16, 4, 0, jnp.float32),
    (2, 8, 2, 64, 16, 4, 0, jnp.float32),     # GQA
    (3, 4, 4, 32, 8, 6, 0, jnp.float32),      # MHA, small blocks
    (4, 2, 1, 128, 16, 5, 0, jnp.float32),    # wide heads
    (2, 8, 8, 64, 8, 4, 0, jnp.float32),
    (4, 4, 1, 64, 16, 5, 24, jnp.float32),    # sliding window
    (2, 8, 2, 64, 16, 4, 0, jnp.bfloat16),
    (3, 6, 6, 64, 8, 4, 0, jnp.bfloat16),
    (2, 4, 2, 32, 8, 6, 12, jnp.bfloat16),    # window + bf16
]


def _assert_ulp(a, b, nulp: int):
    """Elementwise |a - b| <= nulp float32 steps — the tightest portable
    contract between two separately-compiled XLA programs (the CPU
    backend deletes optimization barriers and keeps per-context codegen
    freedom in transcendentals, worth 1-3 ulp on some shapes; a real
    kernel bug is 3+ orders of magnitude larger)."""
    np.testing.assert_array_max_ulp(np.float32(a), np.float32(b),
                                    maxulp=nulp, dtype=np.float32)


@pytest.mark.parametrize("B,Hq,Hkv,hd,bs,mb,win,dt", PAGED_GRID)
def test_paged_decode_kernel_differential(B, Hq, Hkv, hd, bs, mb, win, dt):
    """The differential grid: the Pallas kernel (interpret mode) against
    the streaming jnp oracle — float32 within 4 ulp (bit-exact on
    nearly every shape; see ref.py for why universal bitwise equality
    between separately-compiled XLA programs is not contractable) and
    within dtype tolerance in bfloat16; kernel and oracle must both
    agree with the independent gather-then-softmax reference to
    dtype-tiered tolerance."""
    q, pk, pv, table, lens = _paged_case(B, Hq, Hkv, hd, bs, mb, dt)
    out, lse = paged_decode(q, pk, pv, table, lens, sliding_window=win)
    ro, rl = paged_decode_attention_ref(q, pk, pv, table, lens,
                                        sliding_window=win)
    go, gl = gathered_decode_ref(q, pk, pv, table, lens, sliding_window=win)
    if dt == jnp.float32:
        # out: bitwise on every audited (shape x seed) case — the 4-ulp
        # bound is slack for toolchain drift only. lse: jnp.log keeps
        # per-context codegen freedom (see ref.py), worth <= ~16 ulp.
        _assert_ulp(out, ro, 4)
        _assert_ulp(lse, rl, 32)
    else:
        np.testing.assert_allclose(np.float32(out), np.float32(ro),
                                   atol=tol(dt), rtol=tol(dt))
        np.testing.assert_allclose(np.float32(lse), np.float32(rl),
                                   atol=tol(dt), rtol=tol(dt))
    np.testing.assert_allclose(np.float32(out), np.float32(go),
                               atol=tol(dt), rtol=tol(dt))
    np.testing.assert_allclose(np.float32(lse), np.float32(gl),
                               atol=tol(dt), rtol=tol(dt))


def test_paged_decode_kernel_full_and_single_token_rows():
    """Length edges: a row at exactly full capacity and (via seed reroll)
    rows at 1 token keep the mask honest at both extremes."""
    q, pk, pv, table, lens = _paged_case(2, 4, 2, 64, 16, 3, jnp.float32,
                                         full=True)
    out, _ = paged_decode(q, pk, pv, table, lens)
    ro, _ = paged_decode_attention_ref(q, pk, pv, table, lens)
    _assert_ulp(out, ro, 4)
    lens1 = jnp.ones_like(lens)
    out1, _ = paged_decode(q, pk, pv, table, lens1)
    go1, _ = gathered_decode_ref(q, pk, pv, table, lens1)
    np.testing.assert_allclose(np.float32(out1), np.float32(go1), atol=3e-5,
                               rtol=3e-5)


def test_paged_decode_kernel_ignores_scratch_garbage():
    """Unowned table tails point at scratch block 0, whose contents are
    garbage by design: poisoning scratch must not change any output."""
    q, pk, pv, table, lens = _paged_case(3, 8, 2, 64, 16, 4, jnp.float32)
    out, lse = paged_decode(q, pk, pv, table, lens)
    pk2 = pk.at[0].set(1e9)
    pv2 = pv.at[0].set(-1e9)
    out2, lse2 = paged_decode(q, pk2, pv2, table, lens)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(lse), np.asarray(lse2))


def test_paged_attention_serving_path_kernel_vs_gather():
    """Through the serving entry point (`attention.paged_decode_attention`
    with the scatter of the new token): use_kernel=True and the jnp
    gather path must return bitwise-identical updated pools and
    tolerance-close outputs."""
    from repro.models.attention import paged_decode_attention as serve_paged
    B, Hq, Hkv, hd, bs, mb = 3, 8, 2, 64, 8, 4
    q, pk, pv, table, lens = _paged_case(B, Hq, Hkv, hd, bs, mb, jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    k_new = jax.random.normal(ks[0], (B, 1, Hkv, hd))
    v_new = jax.random.normal(ks[1], (B, 1, Hkv, hd))
    # cache_len = lens - 1 so the scatter stays inside owned blocks
    cache_len = lens - 1
    o_g, pk_g, pv_g = serve_paged(q[:, None], pk, pv, k_new, v_new, table,
                                  cache_len, use_kernel=False)
    o_k, pk_k, pv_k = serve_paged(q[:, None], pk, pv, k_new, v_new, table,
                                  cache_len, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(pk_g), np.asarray(pk_k))
    np.testing.assert_array_equal(np.asarray(pv_g), np.asarray(pv_k))
    np.testing.assert_allclose(np.float32(o_g), np.float32(o_k), atol=3e-5,
                               rtol=3e-5)


# ------------------------------------------------- fused window attention
from repro.kernels.paged_attention.ops import (  # noqa: E402
    paged_window_attention as paged_window)
from repro.kernels.paged_attention.ref import (  # noqa: E402
    gathered_window_ref, paged_window_attention_ref)


def _window_case(B, S, Hq, Hkv, hd, bs, max_blocks, dt, *, seed=0):
    """Window variant of ``_paged_case``: each row holds a ragged base
    length (including 0 — a chunked-prefill first chunk) and owns
    blocks covering ``base + S`` tokens, i.e. the window's K/V is
    already scattered into the pool; table tails stay at scratch."""
    nb = B * max_blocks + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dt)
    pool_k = jax.random.normal(ks[1], (nb, bs, Hkv, hd), dt)
    pool_v = jax.random.normal(ks[2], (nb, bs, Hkv, hd), dt)
    rng = np.random.default_rng(seed + B * 1000 + S * 100 + hd)
    free = list(rng.permutation(np.arange(1, nb)))
    base = np.zeros(B, np.int32)
    table = np.zeros((B, max_blocks), np.int32)
    for b in range(B):
        base[b] = int(rng.integers(0, max_blocks * bs - S + 1))
        for i in range(-(-int(base[b] + S) // bs)):
            table[b, i] = free.pop()
    return q, pool_k, pool_v, jnp.asarray(table), jnp.asarray(base)


# q_len x active-slot count x heads x head_dim x block_size x window x
# dtype; ragged per-row base lengths (incl. mid-block boundaries and
# base = 0) come from _window_case's rng
WINDOW_GRID = [
    (1, 2, 8, 2, 64, 16, 4, 0, jnp.float32),   # degenerate decode shape
    (2, 3, 4, 4, 32, 8, 6, 0, jnp.float32),    # MHA, small blocks
    (2, 2, 8, 2, 64, 16, 4, 0, jnp.float32),   # GQA
    (4, 2, 8, 2, 64, 16, 4, 0, jnp.float32),
    (4, 3, 4, 1, 64, 8, 6, 0, jnp.float32),    # MQA
    (8, 2, 4, 2, 64, 16, 4, 0, jnp.float32),
    (8, 2, 4, 4, 32, 8, 8, 0, jnp.float32),
    (4, 2, 8, 2, 64, 16, 5, 24, jnp.float32),  # sliding window
    (4, 2, 8, 2, 64, 16, 4, 0, jnp.bfloat16),
    (8, 2, 4, 2, 32, 8, 8, 12, jnp.bfloat16),  # window + bf16
]


@pytest.mark.parametrize("S,B,Hq,Hkv,hd,bs,mb,win,dt", WINDOW_GRID)
def test_paged_window_kernel_differential(S, B, Hq, Hkv, hd, bs, mb, win,
                                          dt):
    """The fused multi-token grid: one kernel launch covering S window
    queries per row with causal-in-window masking and per-row base
    lengths, against the streaming oracle (f32: out <= 4 ulp / lse <=
    32 ulp, same contract as the decode grid) and the independent
    gather-then-softmax oracle (dtype-tiered tolerance)."""
    q, pk, pv, table, base = _window_case(B, S, Hq, Hkv, hd, bs, mb, dt)
    out, lse = paged_window(q, pk, pv, table, base, sliding_window=win)
    ro, rl = paged_window_attention_ref(q, pk, pv, table, base,
                                        sliding_window=win)
    go, gl = gathered_window_ref(q, pk, pv, table, base, sliding_window=win)
    if dt == jnp.float32:
        _assert_ulp(out, ro, 4)
        _assert_ulp(lse, rl, 32)
    else:
        np.testing.assert_allclose(np.float32(out), np.float32(ro),
                                   atol=tol(dt), rtol=tol(dt))
        np.testing.assert_allclose(np.float32(lse), np.float32(rl),
                                   atol=tol(dt), rtol=tol(dt))
    np.testing.assert_allclose(np.float32(out), np.float32(go),
                               atol=tol(dt), rtol=tol(dt))
    np.testing.assert_allclose(np.float32(lse), np.float32(gl),
                               atol=tol(dt), rtol=tol(dt))


def test_paged_window_kernel_decode_degenerate():
    """S = 1 windows run the *same* tile shapes and op order as plain
    decode — the fused kernel at q_len 1 is bitwise identical to
    ``paged_decode_attention``, so serving one kernel to all three
    consumers costs decode nothing."""
    q, pk, pv, table, lens = _paged_case(3, 8, 2, 64, 16, 4, jnp.float32)
    od, ld = paged_decode(q, pk, pv, table, lens)
    ow, lw = paged_window(q[:, None], pk, pv, table, lens - 1)
    np.testing.assert_array_equal(np.asarray(od), np.asarray(ow[:, 0]))
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lw[:, 0]))


def test_paged_window_kernel_ignores_scratch_garbage():
    """Scratch poisoning, window edition: unowned table tails point at
    scratch block 0 whose contents are garbage by design — poisoning it
    must not perturb any window output bit."""
    q, pk, pv, table, base = _window_case(3, 4, 8, 2, 64, 16, 4,
                                          jnp.float32)
    out, lse = paged_window(q, pk, pv, table, base)
    out2, lse2 = paged_window(q, pk.at[0].set(1e9), pv.at[0].set(-1e9),
                              table, base)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(lse), np.asarray(lse2))


def test_paged_verify_serving_path_kernel_vs_gather():
    """Through the serving entry point (`attention.paged_verify_attention`
    with the scatter and n_write scratch-diversion): kernel and gather
    paths must leave every *owned* pool block bitwise identical and
    agree on every window position the engine can commit (positions
    past a row's n_write read diverted garbage and are never
    committed — acceptance is capped below them)."""
    from repro.models.attention import paged_verify_attention as sv
    B, S, Hq, Hkv, hd, bs, mb = 3, 4, 8, 2, 64, 8, 6
    q, pk, pv, table, base = _window_case(B, S, Hq, Hkv, hd, bs, mb,
                                          jnp.float32, seed=3)
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    k_new = jax.random.normal(ks[0], (B, S, Hkv, hd))
    v_new = jax.random.normal(ks[1], (B, S, Hkv, hd))
    # full window / partial grant / parked rider (all writes diverted)
    n_write = jnp.asarray([S, 2, 0], jnp.int32)
    o_g, pk_g, pv_g = sv(q, pk, pv, k_new, v_new, table, base, n_write,
                         use_kernel=False)
    o_k, pk_k, pv_k = sv(q, pk, pv, k_new, v_new, table, base, n_write,
                         use_kernel=True)
    np.testing.assert_array_equal(np.asarray(pk_g)[1:], np.asarray(pk_k)[1:])
    np.testing.assert_array_equal(np.asarray(pv_g)[1:], np.asarray(pv_k)[1:])
    og = np.float32(o_g).reshape(B, S, Hq, hd)
    ok = np.float32(o_k).reshape(B, S, Hq, hd)
    for b in range(B):
        c = int(n_write[b])
        np.testing.assert_allclose(ok[b, :c], og[b, :c], atol=3e-5,
                                   rtol=3e-5)


# ---------------------------------------------------------------- ssm scan
from repro.kernels.ssm_scan.ops import selective_scan as pallas_ssm  # noqa: E402
from repro.kernels.ssm_scan.ref import ssm_scan_ref  # noqa: E402


def _ssm_inputs(key, B, T, di, N, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    u = jax.random.normal(ks[0], (B, T, di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, di), dtype)) * 0.1
    Bm = jax.random.normal(ks[2], (B, T, N), dtype)
    Cm = jax.random.normal(ks[3], (B, T, N), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N), jnp.float32) * 0.3)
    D = jnp.ones((di,), jnp.float32)
    s0 = jnp.zeros((B, di, N), jnp.float32)
    return u, dt, Bm, Cm, A, D, s0


@pytest.mark.parametrize("B,T,di,N,bt", [
    (2, 128, 64, 16, 64),
    (1, 96, 128, 16, 32),
    (1, 64, 32, 8, 16),
])
def test_ssm_scan_matches_ref(B, T, di, N, bt):
    args = _ssm_inputs(RNG, B, T, di, N)
    y, sT = pallas_ssm(*args, bt=bt)
    ry, rs = ssm_scan_ref(*args)
    np.testing.assert_allclose(np.float32(y), np.float32(ry), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.float32(sT), np.float32(rs), atol=2e-4,
                               rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_dtypes(dtype):
    args = _ssm_inputs(RNG, 1, 64, 32, 16, dtype)
    y, sT = pallas_ssm(*args, bt=32)
    ry, rs = ssm_scan_ref(*[a.astype(jnp.float32)
                            if a.dtype == jnp.bfloat16 else a for a in args])
    atol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.float32(y), np.float32(ry), atol=atol,
                               rtol=atol)


def test_ssm_scan_state_carry_equals_two_halves():
    B, T, di, N = 1, 64, 32, 16
    u, dt, Bm, Cm, A, D, s0 = _ssm_inputs(RNG, B, 2 * T, di, N)
    yf, sf = pallas_ssm(u, dt, Bm, Cm, A, D, s0, bt=32)
    y1, s1 = pallas_ssm(u[:, :T], dt[:, :T], Bm[:, :T], Cm[:, :T], A, D,
                        s0, bt=32)
    y2, s2 = pallas_ssm(u[:, T:], dt[:, T:], Bm[:, T:], Cm[:, T:], A, D,
                        s1, bt=32)
    np.testing.assert_allclose(np.float32(jnp.concatenate([y1, y2], 1)),
                               np.float32(yf), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.float32(s2), np.float32(sf), atol=1e-4,
                               rtol=1e-4)


def test_ssm_scan_matches_model_block():
    """The kernel agrees with repro.models.ssm.selective_scan — the
    hymba model path it replaces on TPU."""
    from repro.models import ssm as model_ssm
    args = _ssm_inputs(RNG, 2, 64, 32, 16)
    y, sT = pallas_ssm(*args, bt=32)
    my, ms = model_ssm.selective_scan(*args)
    np.testing.assert_allclose(np.float32(y), np.float32(my), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.float32(sT), np.float32(ms), atol=2e-4,
                               rtol=2e-4)
