"""Substrate tests: data packing, chunked checkpoints, optimizer, training
convergence, serving engine, hlo analysis."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic local shim, see requirements-dev
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.train import checkpoint, optimizer as opt_mod
from repro.train.data import DataConfig, PackedLMDataset


# -------------------------------------------------------------------- data
def test_packing_is_deterministic_and_seekable():
    ds = PackedLMDataset(DataConfig(seq_len=32, batch_size=4))
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 33)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=500))
def test_batches_cover_the_stream_without_padding(step):
    ds = PackedLMDataset(DataConfig(seq_len=16, batch_size=2))
    b = ds.batch(step)["tokens"]
    assert (b >= 0).all() and (b < 512).all()
    # packed stream: no padding zeros except genuine EOS separators
    assert (b == 0).mean() < 0.05


def test_resume_matches_continuous_run():
    ds = PackedLMDataset(DataConfig(seq_len=16, batch_size=2))
    run1 = [b["tokens"] for b in ds.batches(6)]
    run2 = [b["tokens"] for b in ds.batches(3)] + \
           [b["tokens"] for b in ds.batches(3, start_step=3)]
    for a, b in zip(run1, run2):
        np.testing.assert_array_equal(a, b)


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_multi_chunk(tmp_path):
    tree = {"a": jnp.arange(100_000, dtype=jnp.float32).reshape(100, 1000),
            "b": {"c": jnp.ones((7,), jnp.bfloat16)}}
    idx = checkpoint.save(tmp_path, "x", tree, chunk_bytes=64 * 1024)
    assert len(idx["leaves"]["a"]["chunks"]) > 1      # actually chunked
    back = checkpoint.restore(tmp_path, "x", like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.ones((4096,), jnp.float32)}
    idx = checkpoint.save(tmp_path, "x", tree, chunk_bytes=1024)
    f = next((tmp_path / "x" / "chunks").iterdir())
    blob = bytearray(f.read_bytes())
    blob[0] ^= 0xFF
    f.write_bytes(bytes(blob))
    with pytest.raises(IOError, match="checksum"):
        checkpoint.restore(tmp_path, "x", like=tree)


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    checkpoint.save(tmp_path, "x", {"w": jnp.ones((4,))})
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(tmp_path, "x", like={"w": jnp.ones((5,))})


# --------------------------------------------------------------- optimizer
def test_adamw_converges_on_quadratic():
    oc = opt_mod.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                             total_steps=200)
    target = jnp.asarray([3.0, -2.0])
    params = {"w": jnp.zeros(2)}
    state = opt_mod.init_state(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, m = opt_mod.apply_updates(params, g, state, oc)
    assert float(loss(params)) < 1e-2


def test_grad_clipping_bounds_update():
    oc = opt_mod.AdamWConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = opt_mod.init_state(params)
    g = {"w": jnp.asarray([1e6, 1e6, 1e6])}
    _, _, m = opt_mod.apply_updates(params, g, state, oc)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip


def test_warmup_cosine_schedule_shape():
    oc = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                             min_lr_ratio=0.1)
    lr0 = float(opt_mod.schedule(oc, jnp.int32(1)))
    lr_peak = float(opt_mod.schedule(oc, jnp.int32(10)))
    lr_end = float(opt_mod.schedule(oc, jnp.int32(100)))
    assert lr0 == pytest.approx(0.1, abs=1e-6)
    assert lr_peak == pytest.approx(1.0, abs=1e-2)
    assert lr_end == pytest.approx(0.1, abs=1e-2)


# ------------------------------------------------------------ train + loss
def test_tiny_model_loss_decreases():
    from repro.train.train_loop import TrainerConfig, train
    cfg = get_config("qwen3-4b").reduced()
    m = build_model(cfg)
    ds = PackedLMDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    batch_size=8))
    tc = TrainerConfig(n_steps=30, log_every=1, ckpt_root="/tmp/ckpt-test",
                       opt=opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5,
                                               total_steps=30))
    res = train(m, ds, tc)
    first = np.mean([h["loss"] for h in res.history[:5]])
    last = np.mean([h["loss"] for h in res.history[-5:]])
    assert last < first - 0.3, (first, last)


# ---------------------------------------------------------------- serving
@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-1.6b", "hymba-1.5b",
                                  "grok-1-314b"])
def test_serving_engine_completes_batches(arch):
    from repro.serve.engine import Request, ServingEngine
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, batch_size=2, max_seq=64)
    reqs = [Request(i, prompt=list(range(2, 10)), max_new_tokens=4)
            for i in range(3)]
    done = eng.run(reqs)
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)
    assert eng.metrics["completed"] == 3


# ----------------------------------------------------------- hlo analysis
def test_hlo_parser_counts_loop_flops():
    from repro.launch import hlo_analysis

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0]

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((10, 128, 128), jnp.float32),
    ).compile().as_text()
    stats = hlo_analysis.analyze(txt)
    assert stats.loops and stats.loops[0][1] == 10
    assert stats.flops == pytest.approx(10 * 2 * 128 ** 3, rel=0.01)


def test_hlo_parser_shape_bytes():
    from repro.launch.hlo_analysis import shape_bytes
    assert shape_bytes("bf16[4,8]") == 64
    assert shape_bytes("f32[2,2]") == 16
    assert shape_bytes("(s32[], f32[10])") == 4 + 40
    assert shape_bytes("pred[7]") == 7


def test_hlo_parser_scan_slice_traffic_not_overcounted():
    """A scan that dynamic-slices one row per step from a big buffer must
    count ~rows, not trips x full buffer (the rwkv/KV-cache case)."""
    from repro.launch import hlo_analysis

    T, D = 64, 256

    def f(buf):
        def body(c, i):
            row = jax.lax.dynamic_slice(buf, (i, 0), (1, D))
            return c + row[0], None
        return jax.lax.scan(body, jnp.zeros(D), jnp.arange(T))[0]

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((T, D), jnp.float32)).compile().as_text()
    stats = hlo_analysis.analyze(txt)
    full_buffer_per_step = T * (T * D * 4)   # the overcounting failure mode
    assert stats.hbm_bytes < 0.2 * full_buffer_per_step, stats.hbm_bytes
    assert stats.hbm_bytes >= T * D * 4      # at least one full pass
