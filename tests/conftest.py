import os
import sys
from pathlib import Path

# src layout without install
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# Tests run on the single real CPU device; only launch/dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
