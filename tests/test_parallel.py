"""Parallel dispatch: output equivalence across modes + the paper's core
claim that parallel fan-out beats sequential when services overlap."""
import random
import time

from repro.core.parallel import ParallelDispatcher
from repro.core.services import LatencyModel, Replica, Service


def make_services(latency=None, n=5):
    out = {}
    for i in range(n):
        name = f"svc{i}"
        s = Service(name, replicas=[
            Replica(f"{name}/0", lambda p, i=i: [(t, f"L{i}") for t in p],
                    latency=latency)])
        s.start()
        out[name] = s
    return out


def calls_for(services, payload=("tok",)):
    return [(n, s, list(payload)) for n, s in services.items()]


def test_parallel_equals_sequential_outputs():
    svcs = make_services()
    seq = ParallelDispatcher(mode="sequential")
    par = ParallelDispatcher(mode="thread")
    r1 = seq(calls_for(svcs))
    r2 = par(calls_for(svcs))
    assert r1.outputs == r2.outputs
    par.shutdown()


def test_parallel_speedup_with_latency_model():
    """With remote-like service latencies (the paper's situation), thread
    fan-out overlaps the waits: T_p << T_s == sum(T_i). Paper Fig 8
    reports 1.792s -> 0.568s (3.15x) for 5 services."""
    lat = LatencyModel(median_s=0.05, p75_s=0.055)
    svcs = make_services(latency=lat)
    rng = random.Random(0)
    seq = ParallelDispatcher(mode="sequential", rng=rng)
    par = ParallelDispatcher(mode="thread", max_workers=8,
                             rng=random.Random(0))
    t0 = time.perf_counter()
    seq(calls_for(svcs))
    t_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    par(calls_for(svcs))
    t_p = time.perf_counter() - t0
    assert t_p < t_s / 2, (t_p, t_s)   # >=2x with 5 overlapping services
    par.shutdown()


def test_dispatch_result_accounting():
    svcs = make_services(n=3)
    par = ParallelDispatcher(mode="thread")
    res = par(calls_for(svcs))
    assert set(res.per_call_s) == set(svcs)
    assert res.sequential_equivalent_s >= 0
    assert res.speedup >= 0
    par.shutdown()


def test_jax_async_mode():
    import jax
    import jax.numpy as jnp

    def heavy(p):
        x = jnp.ones((64, 64)) * p["scale"]
        return (x @ x).sum()

    svcs = {}
    for i in range(3):
        s = Service(f"m{i}", replicas=[Replica(f"m{i}/0",
                                               jax.jit(heavy))])
        s.start()
        svcs[f"m{i}"] = s
    d = ParallelDispatcher(mode="jax_async")
    res = d([(n, s, {"scale": float(i)}) for i, (n, s) in
             enumerate(svcs.items())])
    assert float(res.outputs["m0"]) == 0.0
    assert float(res.outputs["m1"]) > 0.0
