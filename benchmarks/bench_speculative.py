"""Speculative draft-and-verify decode: acceptance-rate sweep over
draft quality x window size, tokens per target step, and wall-clock vs
the non-speculative baseline.

Three drafts span the quality axis against one target:

* **self** — the target's own weights: greedy proposals ARE the target
  argmax, so acceptance is total and every verify step commits k+1
  tokens (the upper bound, and the headline check: tokens/step > 1).
* **half** — the target's first half of layers (a free "distilled"
  draft: the stacked block params sliced on the layer axis): cheaper
  and partially agreeing.
* **cold** — the same architecture at a different random init:
  acceptance ~ 0, the adversarial floor. Even here the stream must stay
  exactly the baseline stream — rejected windows cost a step but never
  correctness.

Every scenario cross-checks the greedy stream against the
non-speculative engine token-for-token (the bit-identity regression in
``tests/test_speculative.py``, re-validated on the bench workload).

    PYTHONPATH=src python -m benchmarks.bench_speculative
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine

MAX_SEQ = 64
B = 4
MAX_NEW = 12
LENS = (5, 11, 7, 14)


def _reqs(cfg, seed=1):
    rng = jax.random.key(seed)
    out = []
    for i, L in enumerate(LENS):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=MAX_NEW,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist()))
    return out


def _half_layer_draft(cfg, params):
    """A free draft: the target's bottom half of the layer stack. Block
    params are stacked (L, ...) for the scan, so the slice is a tree
    map; embeddings/head are shared."""
    half = max(cfg.n_layers // 2, 1)
    dcfg = dataclasses.replace(cfg, n_layers=half)
    dparams = dict(params)
    dparams["blocks"] = jax.tree.map(lambda x: x[:half], params["blocks"])
    return build_model(dcfg), dparams


def _serve(eng, reqs):
    t0 = time.perf_counter()
    done = eng.run(list(reqs))
    wall = time.perf_counter() - t0
    assert len(done) == len(reqs)
    return wall


def run(report) -> None:
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    half_model, half_params = _half_layer_draft(cfg, params)
    drafts = {
        "self": (model, params),
        "half": (half_model, half_params),
        "cold": (model, model.init(jax.random.key(9))),
    }

    base_reqs = _reqs(cfg)
    base = ServingEngine(model, params, batch_size=B, max_seq=MAX_SEQ,
                         paged=True, block_size=8)
    base_wall = _serve(base, base_reqs)
    base_steps = base.metrics["decode_steps"]
    base_tokens = sum(len(r.out_tokens) for r in base_reqs)
    report.row("speculative.baseline.wall_s", round(base_wall, 3), "s",
               f"{B} requests x {MAX_NEW} tokens, non-speculative")
    report.row("speculative.baseline.decode_steps", base_steps, "steps", "")

    best_tps = 0.0
    for k in (2, 4):
        for name, (dm, dp) in drafts.items():
            eng = ServingEngine(model, params, batch_size=B,
                                max_seq=MAX_SEQ, paged=True, block_size=8,
                                draft_model=dm, draft_params=dp,
                                speculation=k)
            reqs = _reqs(cfg)
            wall = _serve(eng, reqs)
            m = eng.metrics
            accept = m["spec_accepted"] / max(m["spec_proposed"], 1)
            # tokens committed by decode/verify steps (prefill emits one
            # per request outside the step loop)
            emitted = sum(len(r.out_tokens) for r in reqs) - len(reqs)
            tps = emitted / max(m["decode_steps"], 1)
            # per-SLOT tokens per target step: the speculative
            # multiplier (a non-speculative batch scores exactly 1.0)
            slot_tps = tps / B
            tag = f"speculative.k{k}.{name}"
            report.row(f"{tag}.accept_rate", round(accept, 3), "frac",
                       f"{m['spec_accepted']}/{m['spec_proposed']} "
                       "proposals accepted")
            report.row(f"{tag}.tokens_per_step", round(tps, 2), "tok/step",
                       f"{emitted} tokens in {m['decode_steps']} target "
                       "steps, batch-wide")
            report.row(f"{tag}.tokens_per_slot_step", round(slot_tps, 2),
                       "tok/slot/step", "non-speculative baseline = 1.0")
            report.row(f"{tag}.wall_s", round(wall, 3), "s",
                       f"baseline {base_wall:.3f}s")
            report.row(f"{tag}.draft_steps", m["draft_steps"], "steps",
                       "small-model decode steps spent proposing")
            ok = all(a.out_tokens == b.out_tokens
                     for a, b in zip(base_reqs, reqs))
            report.check(f"greedy stream identical under k={k} {name} "
                         "draft", ok, f"{len(reqs)} streams compared")
            assert eng.pool.available == eng.pool.total
            if name == "self":
                best_tps = max(best_tps, slot_tps)
                report.check(
                    f"self-draft k={k} uses fewer target steps",
                    m["decode_steps"] < base_steps,
                    f"{m['decode_steps']} vs {base_steps} baseline steps")

    report.check("high-acceptance draft commits > 1 token per slot per "
                 "target step", best_tps > 1.0,
                 f"best tokens/slot/step {best_tps:.2f} "
                 "(non-speculative = 1.0)")
    report.row("speculative.total_tokens", base_tokens, "tokens",
               "per scenario, streams all identical")

    # fused verify kernel (interpret mode off-TPU): the same workload
    # with every verify window in ONE Pallas launch. Stream identity
    # against the non-speculative baseline is the self-check, and the
    # dispatch counters prove the fused path actually ran.
    keng = ServingEngine(model, params, batch_size=B, max_seq=MAX_SEQ,
                         paged=True, block_size=8, use_kernel=True,
                         draft_model=model, draft_params=params,
                         speculation=2)
    kreqs = _reqs(cfg)
    kwall = _serve(keng, kreqs)
    km = keng.metrics
    report.row("speculative.kernel.k2.wall_s", round(kwall, 3), "s",
               "fused verify kernel, self draft")
    report.row("speculative.kernel.k2.kernel_windows",
               km["kernel_windows"], "launches",
               "one fused launch per verify tick")
    report.row("speculative.kernel.k2.kernel_positions",
               km["kernel_positions"], "positions",
               "real query positions through the paged kernel")
    report.check("greedy stream identical under fused verify kernel",
                 all(a.out_tokens == b.out_tokens
                     for a, b in zip(base_reqs, kreqs)),
                 f"{len(kreqs)} streams compared")
    report.check("fused verify kernel dispatched multi-token windows",
                 km["kernel_windows"] > 0
                 and km["kernel_positions"] > km["kernel_windows"],
                 f"{km['kernel_windows']} windows, "
                 f"{km['kernel_positions']} positions")
    assert keng.pool.available == keng.pool.total


if __name__ == "__main__":
    from benchmarks.report import Report

    rep = Report(verbose=True)
    run(rep)
    raise SystemExit(1 if rep.n_failed else 0)
