"""Kernel micro-benchmarks (deliverable d).

On this CPU container the Pallas kernels execute in interpret mode, so
absolute wall-times are NOT TPU predictions. What this benchmark reports:

  * correctness deltas vs the pure-jnp oracle at benchmark shapes
  * analytic FLOPs / bytes / arithmetic intensity per kernel shape
    (the numbers the BlockSpec tiling was designed around)
  * wall time of the jnp reference (the XLA-compiled path actually used
    for CPU smoke runs)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rwkv_scan.ops import wkv
from repro.kernels.rwkv_scan.ref import wkv_ref


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.max(np.abs(a - b) / (np.abs(b).max() + 1e-6)))


def _time(fn, *args, repeats=3):
    out = jax.block_until_ready(fn(*args))        # compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return out, sorted(ts)[len(ts) // 2]


def run(report) -> None:
    key = jax.random.key(0)

    # ---------------------------------------------------- flash attention
    B, H, S, hd = 1, 4, 512, 64
    q, k, v = (jax.random.normal(kk, (B, H, S, hd), jnp.float32)
               for kk in jax.random.split(key, 3))
    flops = 4.0 * B * H * S * S * hd / 2            # causal halves the work
    bytes_ = 4 * (3 * B * H * S * hd + B * H * S * hd)
    ker = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                  bq=128, bk=128))
    ref = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    out_k, _ = _time(ker, q, k, v, repeats=1)       # interpret mode: slow
    out_r, t_ref = _time(ref, q, k, v)
    report.row("kernels/flash_attention/rel_err", _rel_err(out_k, out_r),
               "", f"B{B}H{H}S{S}hd{hd}")
    report.row("kernels/flash_attention/ref_us", round(t_ref * 1e6, 1),
               "us_per_call",
               f"flops={flops:.3g} AI={flops/bytes_:.1f} flop/byte")
    report.check("kernels/flash_attention/allclose",
                 _rel_err(out_k, out_r) < 2e-3, "interpret vs oracle")

    # ---------------------------------------------------- decode attention
    B, Hq, Hkv, T, hd = 4, 8, 2, 2048, 64
    q1 = jax.random.normal(key, (B, Hq, hd), jnp.float32)
    k1 = jax.random.normal(key, (B, Hkv, T, hd), jnp.float32)
    v1 = jax.random.normal(key, (B, Hkv, T, hd), jnp.float32)
    nv = jnp.int32(T - 3)
    flops = 4.0 * B * Hq * T * hd
    bytes_ = 4 * (2 * B * Hkv * T * hd)             # KV reads dominate
    ker = jax.jit(lambda q, k, v: decode_attention(q, k, v, nv, bk=256)[0])
    ref = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, nv)[0])
    out_k, _ = _time(ker, q1, k1, v1, repeats=1)
    out_r, t_ref = _time(ref, q1, k1, v1)
    report.row("kernels/decode_attention/rel_err", _rel_err(out_k, out_r),
               "", f"B{B}Hq{Hq}Hkv{Hkv}T{T}")
    report.row("kernels/decode_attention/ref_us", round(t_ref * 1e6, 1),
               "us_per_call",
               f"AI={flops/bytes_:.2f} flop/byte (memory-bound by design)")
    report.check("kernels/decode_attention/allclose",
                 _rel_err(out_k, out_r) < 2e-3, "interpret vs oracle")

    # ---------------------------------------------------- rwkv wkv scan
    B, T, H, hd = 2, 256, 4, 32          # layout (B, T, H, hd)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    kk = jax.random.normal(ks[1], (B, T, H, hd), jnp.float32)
    vv = jax.random.normal(ks[2], (B, T, H, hd), jnp.float32)
    # data-dependent decay in (0,1): w = exp(-exp(x)) as in RWKV6
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hd),
                                           jnp.float32)))
    u = jax.random.normal(ks[4], (H, hd), jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    flops = 4.0 * B * H * T * hd * hd
    bytes_ = 4 * (4 * B * H * T * hd + B * H * hd * hd)
    ker = jax.jit(lambda r, k, v, w: wkv(r, k, v, w, u, s0, bt=64)[0])
    ref = jax.jit(lambda r, k, v, w: wkv_ref(r, k, v, w, u, s0)[0])
    out_k, _ = _time(ker, r, kk, vv, w, repeats=1)
    out_r, t_ref = _time(ref, r, kk, vv, w)
    report.row("kernels/rwkv_scan/rel_err", _rel_err(out_k, out_r), "",
               f"B{B}H{H}T{T}hd{hd}")
    report.row("kernels/rwkv_scan/ref_us", round(t_ref * 1e6, 1),
               "us_per_call", f"AI={flops/bytes_:.1f} flop/byte")
    report.check("kernels/rwkv_scan/allclose",
                 _rel_err(out_k, out_r) < 2e-3, "interpret vs oracle")

    # ---------------------------------------------------- selective ssm
    from repro.kernels.ssm_scan.ops import selective_scan
    from repro.kernels.ssm_scan.ref import ssm_scan_ref
    B, T, di, N = 2, 256, 128, 16
    ks = jax.random.split(key, 5)
    u = jax.random.normal(ks[0], (B, T, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, di))) * 0.1
    Bm = jax.random.normal(ks[2], (B, T, N), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, T, N), jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.3)
    Dv = jnp.ones((di,), jnp.float32)
    s0 = jnp.zeros((B, di, N), jnp.float32)
    flops = 6.0 * B * T * di * N
    bytes_ = 4 * (2 * B * T * di + 2 * B * T * N + B * T * di)
    ker = jax.jit(lambda *a: selective_scan(*a, bt=64)[0])
    ref = jax.jit(lambda *a: ssm_scan_ref(*a)[0])
    out_k, _ = _time(ker, u, dt, Bm, Cm, A, Dv, s0, repeats=1)
    out_r, t_ref = _time(ref, u, dt, Bm, Cm, A, Dv, s0)
    report.row("kernels/ssm_scan/rel_err", _rel_err(out_k, out_r), "",
               f"B{B}T{T}di{di}N{N}")
    report.row("kernels/ssm_scan/ref_us", round(t_ref * 1e6, 1),
               "us_per_call", f"AI={flops/bytes_:.1f} flop/byte; XLA scan "
               f"round-trips state (di x N) per step — VMEM-resident in "
               f"the kernel")
    report.check("kernels/ssm_scan/allclose",
                 _rel_err(out_k, out_r) < 2e-3, "interpret vs oracle")
