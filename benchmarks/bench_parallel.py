"""Reproduce the paper's Fig 8 / Table 6: parallel vs sequential
multi-PaaS dispatch inside the CV-Parser pipeline.

Two modes (DESIGN.md §3, assumption 1):

  * latency-model — faithful reproduction. Each section PaaS replica
    carries the paper's Fig-7 per-service latency distribution (the five
    services are remote machines from the parser's point of view; this
    container has 1 core, so remote service time is simulated). The
    paper's claim: median service phase 1.792 s sequential -> 0.568 s
    parallel (>3.1x); total 2.093 s -> 0.871 s (2.4x).

  * real-compute  — the actual JAX NER models run in-process (no latency
    model). This validates the pipeline end-to-end and reports the
    measured speedup WITHOUT asserting >3x: with one physical core,
    compute-bound fan-out cannot exceed 1x (documented, not hidden).

Latencies below are calibrated so the five medians sum to the paper's
sequential median (~1.79 s) with work_experience the slowest (Fig 7).
"""
from __future__ import annotations

import random
import statistics

from repro.core import cvdata
from repro.core.parallel import ParallelDispatcher
from repro.core.pipeline import CVParser
from repro.core.services import LatencyModel

# paper Fig 7 shape: work_experience dominates; medians sum ~1.79 s.
FIG7_LATENCY = {
    "personal_information": LatencyModel(0.33, 0.45),
    "education":            LatencyModel(0.27, 0.36),
    "work_experience":      LatencyModel(0.55, 0.87),
    "skills":               LatencyModel(0.32, 0.44),
    "functional_area":      LatencyModel(0.32, 0.42),
}
PAPER_SEQ_MEDIAN_S = 1.792
PAPER_PAR_MEDIAN_S = 0.568
PAPER_TOTAL_SEQ_S = 2.093
PAPER_TOTAL_PAR_S = 0.871

# scaled-down clock so 2x60 documents fit the CPU budget: all latency
# medians are multiplied by SCALE; ratios (the paper's claim) are
# scale-invariant.
SCALE = 0.05
N_DOCS = 60


def _build(mode: str, seed: int = 0):
    import jax
    parser = CVParser.create(jax.random.key(0),
                             dispatcher=ParallelDispatcher(
                                 mode=mode, rng=random.Random(seed)))
    if mode != "real":
        for name, svc in parser.services.items():
            lm = FIG7_LATENCY[name]
            for r in svc.replicas:
                r.latency = LatencyModel(lm.median_s * SCALE,
                                         lm.p75_s * SCALE)
    return parser


def _run_corpus(parser, docs):
    svc_phase, totals, seq_equiv = [], [], []
    for d in docs:
        out = parser.parse(d)
        svc_phase.append(out["timings"]["parallel_services"])
        totals.append(out["timings"]["total"])
        seq_equiv.append(out["dispatch"].sequential_equivalent_s)
    return (statistics.median(svc_phase), statistics.median(totals),
            statistics.median(seq_equiv))


def run(report) -> None:
    rng = random.Random(7)
    docs = [cvdata.make_document(rng) for _ in range(N_DOCS)]

    # ------------------------------------------------- latency-model mode
    par = _build("thread")
    seq = _build("sequential")
    p_svc, p_tot, _ = _run_corpus(par, docs)
    s_svc, s_tot, _ = _run_corpus(seq, docs)
    speed_svc = s_svc / p_svc
    speed_tot = s_tot / p_tot
    paper_svc = PAPER_SEQ_MEDIAN_S / PAPER_PAR_MEDIAN_S      # 3.15x
    paper_tot = PAPER_TOTAL_SEQ_S / PAPER_TOTAL_PAR_S        # 2.40x
    report.row("parallel/latmodel/service_median_s",
               round(p_svc / SCALE, 3), "s",
               f"paper={PAPER_PAR_MEDIAN_S}")
    report.row("parallel/latmodel/service_median_seq_s",
               round(s_svc / SCALE, 3), "s",
               f"paper={PAPER_SEQ_MEDIAN_S}")
    report.row("parallel/latmodel/service_speedup", round(speed_svc, 2),
               "x", f"paper={paper_svc:.2f}x")
    report.row("parallel/latmodel/total_speedup", round(speed_tot, 2),
               "x", f"paper={paper_tot:.2f}x")
    report.check("parallel/latmodel/speedup>3x", speed_svc > 3.0,
                 f"{speed_svc:.2f}x (paper {paper_svc:.2f}x)")
    report.check("parallel/latmodel/median_matches_paper",
                 abs(p_svc / SCALE - PAPER_PAR_MEDIAN_S)
                 < 0.25 * PAPER_PAR_MEDIAN_S,
                 f"{p_svc / SCALE:.3f}s vs paper {PAPER_PAR_MEDIAN_S}s")

    # ------------------------------------------------- real-compute mode
    rp = _build("real-thread"[5:])          # "thread" without latency model
    rs = CVParser.create(dispatcher=ParallelDispatcher(mode="sequential"))
    few = docs[:20]
    rp_svc, rp_tot, _ = _run_corpus(rp, few)
    rs_svc, rs_tot, _ = _run_corpus(rs, few)
    report.row("parallel/real/service_median_ms", round(rp_svc * 1e3, 2),
               "ms", f"sequential={rs_svc*1e3:.2f}ms")
    report.row("parallel/real/speedup", round(rs_svc / rp_svc, 2), "x",
               "1 physical core: ~1x expected (DESIGN.md assumption 2)")

    table = "\n".join([
        "mode | service phase (median) | total (median) | speedup",
        "--- | --- | --- | ---",
        f"paper sequential | {PAPER_SEQ_MEDIAN_S} s | {PAPER_TOTAL_SEQ_S} s | 1.0x",
        f"paper parallel | {PAPER_PAR_MEDIAN_S} s | {PAPER_TOTAL_PAR_S} s | {paper_svc:.2f}x",
        f"ours (latency-model, rescaled) sequential | {s_svc/SCALE:.3f} s | {s_tot/SCALE:.3f} s | 1.0x",
        f"ours (latency-model, rescaled) parallel | {p_svc/SCALE:.3f} s | {p_tot/SCALE:.3f} s | {speed_svc:.2f}x",
        f"ours (real-compute, 1 core) parallel | {rp_svc*1e3:.1f} ms | {rp_tot*1e3:.1f} ms | {rs_svc/rp_svc:.2f}x",
    ])
    report.table("Fig 8 / Table 6 — parallel vs sequential dispatch", table)
