"""Reproduce the paper's Tables 7/8: CV-Parser response time vs
(concurrency x number-of-requests).

The paper drives its deployed cluster (each PaaS on 3 machines: 2
round-robin primaries + 1 backup, behind NGINX; a 40-core Xeon front
box) with Apache Bench. Claims: (a) <= 2.5 s average response at
concurrency 30 for any request count; (b) a knee past concurrency 30
(at 50, average 3.15 s, p75 > 2.5 s); (c) "normal CV in < 700 ms" for
sequential flow (Table 8, c=1: 0.686 s).

This container is 1 core (repro band 2: hardware gate), so the cluster
is SIMULATED with the framework's own deployment substrate — Service /
Replica (finite worker slots) / RoundRobinBalancer / ParallelDispatcher
— parameterized by the paper's own stage measurements (Table 6 medians,
Fig 7 service shape). The validation is that the paper's deployment
topology + its stage latencies reproduce its Tables 7/8 end-to-end
numbers; real model compute runs in bench_parallel's real-compute mode.

Calibration: stage medians (Table 6: tika .044 + sectioning .016 + bert
.211; services: Fig-7 shape, work_experience slowest at .55) are scaled
by CAL so the simulated c=1 average lands on Table 8's 0.686 s — Table 6
and Table 8 come from different paper runs and disagree by ~18%.
"""
from __future__ import annotations

import random
import statistics
import time
from concurrent.futures import ThreadPoolExecutor

from benchmarks.bench_parallel import FIG7_LATENCY
from repro.core.balancer import deploy
from repro.core.parallel import ParallelDispatcher
from repro.core.services import LatencyModel, Replica, Service

CONCURRENCIES = [1, 3, 5, 10, 30, 50]
N_REQ = {1: 24, 3: 30, 5: 40, 10: 60, 30: 90, 50: 100}
PAPER_T8 = {1: 0.686, 3: 0.728, 5: 0.778, 10: 0.863, 30: 1.847, 50: 3.146}
FRONT = LatencyModel(0.271, 0.33)       # tika+sectioning+bert (Table 6)
CAL = 0.686 / (0.271 + 0.55)            # reconcile Table 6 vs Table 8 runs
SPREAD = 1.06          # p75/p50 per stage — Table 8 c=1 measures 1.046
WORKERS_PER_REPLICA = 5                 # paper: unstated; fitted once


class SimulatedCluster:
    """The paper's deployment, §4.3: per-PaaS 2 primaries + 1 backup with
    finite worker slots; front-end stages; parallel fan-out."""

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.services = {}
        for name, lm in FIG7_LATENCY.items():
            lat = LatencyModel(lm.median_s * CAL,
                               lm.median_s * CAL * SPREAD)
            reps = [Replica(f"{name}/{i}", lambda p: p, latency=lat,
                            max_concurrency=WORKERS_PER_REPLICA,
                            backup=(i == 2)) for i in range(3)]
            svc = Service(name, replicas=reps)
            svc.start()
            deploy(svc)
            self.services[name] = svc
        self.dispatcher = ParallelDispatcher(mode="thread", max_workers=512,
                                             rng=self.rng)
        self.front = LatencyModel(FRONT.median_s * CAL,
                                  FRONT.median_s * CAL * SPREAD)

    def parse(self, doc) -> float:
        t0 = time.perf_counter()
        time.sleep(self.front.sample(self.rng))          # tika+bert+section
        calls = [(n, s, doc) for n, s in self.services.items()]
        self.dispatcher(calls)
        return time.perf_counter() - t0


def run(report) -> None:
    cluster = SimulatedCluster()
    rows = ["concurrency | avg (s) | p50 | p75 | p95 | paper avg (s)",
            "--- | --- | --- | --- | --- | ---"]
    avg_by_c = {}
    for conc in CONCURRENCIES:
        n = N_REQ[conc]
        with ThreadPoolExecutor(max_workers=conc) as client:
            lat = list(client.map(cluster.parse, [f"cv{i}" for i in range(n)]))
        q = statistics.quantiles(lat, n=20)
        avg = statistics.mean(lat)
        avg_by_c[conc] = avg
        rows.append(f"{conc} | {avg:.3f} | {statistics.median(lat):.3f} | "
                    f"{q[14]:.3f} | {q[18]:.3f} | {PAPER_T8[conc]:.3f}")
        report.row(f"concurrency/{conc}/avg_response_s", round(avg, 3), "s",
                   f"paper={PAPER_T8[conc]}")
    report.table("Tables 7/8 — response time vs concurrency (simulated "
                 "cluster, paper stage latencies)", "\n".join(rows))

    report.check("concurrency/c1_under_700ms", avg_by_c[1] < 0.75,
                 f"{avg_by_c[1]:.3f}s (paper 0.686s; abstract <700ms)")
    report.check("concurrency/c30_under_2.5s", avg_by_c[30] < 2.5,
                 f"{avg_by_c[30]:.3f}s (paper claim <=2.5s, measured 1.847s)")
    report.check("concurrency/knee_past_30",
                 avg_by_c[50] > 1.4 * avg_by_c[30],
                 f"c50={avg_by_c[50]:.3f}s vs c30={avg_by_c[30]:.3f}s "
                 f"(paper 3.146 vs 1.847)")
    report.check("concurrency/monotone",
                 all(avg_by_c[a] <= avg_by_c[b] * 1.15 for a, b in
                     zip(CONCURRENCIES, CONCURRENCIES[1:])),
                 str({k: round(v, 2) for k, v in avg_by_c.items()}))
