"""Shared reporting for the benchmark suite.

Every benchmark module exposes ``run(report)`` and emits:
  * rows   — ``name,value,unit,derived`` CSV (machine-readable results)
  * checks — pass/fail validations against the paper's claims
  * tables — markdown tables (printed with --verbose, saved with --save)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path


def run_metadata() -> dict:
    """Provenance stamp for a benchmark run: git SHA, library versions,
    platform, and an ISO-8601 UTC timestamp — so a results.json in the
    CI artifact trail identifies exactly what produced it. Every field
    degrades to ``"unknown"`` rather than failing the run (e.g. a
    tarball checkout with no .git)."""
    import platform
    import subprocess
    from datetime import datetime, timezone
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:  # noqa: BLE001 — provenance must not fail the run
        sha = "unknown"
    versions = {}
    for mod in ("jax", "jaxlib", "numpy"):
        try:
            versions[mod] = __import__(mod).__version__
        except Exception:  # noqa: BLE001
            versions[mod] = "unknown"
    return {
        "git_sha": sha,
        "versions": versions,
        "platform": platform.platform(),
        "python": platform.python_version(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
    }


@dataclass
class Report:
    verbose: bool = False
    rows: list = field(default_factory=list)
    checks: list = field(default_factory=list)
    tables: list = field(default_factory=list)

    def row(self, name: str, value, unit: str = "", derived: str = ""):
        self.rows.append((name, value, unit, derived))
        print(f"{name},{value},{unit},{derived}", flush=True)

    def check(self, name: str, ok: bool, detail: str = ""):
        self.checks.append((name, bool(ok), detail))
        print(f"CHECK {'PASS' if ok else 'FAIL'} {name}: {detail}",
              flush=True)

    def table(self, title: str, markdown: str):
        self.tables.append((title, markdown))
        if self.verbose:
            print(f"\n## {title}\n{markdown}\n", flush=True)

    # ------------------------------------------------------------- timing
    def timeit(self, name: str, fn, *, repeats: int = 5, warmup: int = 1,
               derived: str = ""):
        """Median-of-repeats wall time; records a row in µs per call."""
        for _ in range(warmup):
            fn()
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        med = sorted(times)[len(times) // 2]
        self.row(name, round(med * 1e6, 1), "us_per_call", derived)
        return med

    # ------------------------------------------------------------- saving
    def save(self, path: Path):
        path.mkdir(parents=True, exist_ok=True)
        csv = "\n".join(f"{n},{v},{u},{d}" for n, v, u, d in self.rows)
        (path / "results.csv").write_text(csv + "\n")
        md = "\n\n".join(f"## {t}\n{m}" for t, m in self.tables)
        (path / "tables.md").write_text(md + "\n")
        checks = "\n".join(f"{'PASS' if ok else 'FAIL'} {n}: {d}"
                           for n, ok, d in self.checks)
        (path / "checks.txt").write_text(checks + "\n")
        # machine-readable snapshot for the CI bench-regression artifact
        # (the perf trajectory lives in these JSONs, one per run)
        import json
        (path / "results.json").write_text(json.dumps({
            "meta": run_metadata(),
            "rows": [{"name": n, "value": v, "unit": u, "derived": d}
                     for n, v, u, d in self.rows],
            "checks": [{"name": n, "ok": ok, "detail": d}
                       for n, ok, d in self.checks],
            "n_failed": self.n_failed,
        }, indent=2, default=str) + "\n")

    @property
    def n_failed(self) -> int:
        return sum(1 for _, ok, _ in self.checks if not ok)
