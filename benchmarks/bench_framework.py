"""Re-run the paper's §3.1 methodology (Apache-Bench over three scenarios,
then AHP) against three *serving executor backends* we can actually host
in this container — the in-process analogue of Falcon/FastApi/Flask.

Backends (alternatives):
  * direct   — handler called inline (the "minimalist WSGI" end of the
               spectrum: no queueing, no event loop)
  * thread   — fixed thread-pool with a request queue (classic WSGI
               worker-pool server)
  * asyncio  — single event loop, handlers wrapped as coroutines

Scenarios (the paper's, one-factor-at-a-time):
  * hello_world    — constant payload
  * fibonacci      — CPU-bound: 100th Fibonacci term (paper §3.1.2)
  * file_retrieval — IO-bound: read a blob from the GridFS-style chunked
                     checkpoint store and write it back to disk

Criteria measured per (backend, scenario) mirror the Ab tool's: requests/s,
time per request, time per concurrent batch, total bytes, transfer rate,
total time. AHP (same preference functions as the paper) then selects the
backend. The paper's conclusion shape — the minimal direct-dispatch stack
wins CPU-light scenarios while IO-bound narrows the gap — is asserted.
"""
from __future__ import annotations

import asyncio
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

from repro.core.ahp import Criterion, run_ahp

N_REQUESTS = 600
CONCURRENCY = 30


# ----------------------------------------------------------------- handlers
def h_hello(_):
    return b"hello world"


def h_fibonacci(_):
    a, b = 0, 1
    for _ in range(100):
        a, b = b, a + b
    return str(a).encode()


def make_file_handler(tmp: Path):
    import numpy as np

    from repro.train import checkpoint
    blob = np.frombuffer(bytes(range(256)) * 256, np.uint8)   # 64 KiB
    checkpoint.save(tmp / "gridfs", "cv.pdf", {"doc": blob},
                    chunk_bytes=16384)
    out = tmp / "retrieved"

    def h_file(i):
        tree = checkpoint.restore(tmp / "gridfs", "cv.pdf")
        data = np.asarray(tree["doc"]).tobytes()
        out.write_bytes(data)
        return data[:64]
    return h_file


# ----------------------------------------------------------------- backends
def run_direct(handler, n, conc):
    total = 0
    for i in range(n):
        total += len(handler(i))
    return total


def run_thread(handler, n, conc):
    with ThreadPoolExecutor(max_workers=conc) as pool:
        return sum(len(r) for r in pool.map(handler, range(n)))


def run_asyncio(handler, n, conc):
    async def main():
        sem = asyncio.Semaphore(conc)

        async def one(i):
            async with sem:
                return len(handler(i))
        return sum(await asyncio.gather(*[one(i) for i in range(n)]))
    return asyncio.run(main())


BACKENDS = {"direct": run_direct, "thread": run_thread,
            "asyncio": run_asyncio}

CRITERIA = [
    Criterion("Requests per second", higher_is_better=True),
    Criterion("Time per request", higher_is_better=False),
    Criterion("Time per concurrent request", higher_is_better=False),
    Criterion("Transfer rate", higher_is_better=True),
    Criterion("Total transferred", higher_is_better=True),
    Criterion("Time taken for tests", higher_is_better=False),
]


def measure(backend_fn, handler, n=N_REQUESTS, conc=CONCURRENCY) -> dict:
    t0 = time.perf_counter()
    total_bytes = backend_fn(handler, n, conc)
    wall = time.perf_counter() - t0
    return {
        "Requests per second": n / wall,
        "Time per request": wall / n * 1e3,              # ms
        "Time per concurrent request": wall / n * conc * 1e3,
        "Transfer rate": total_bytes / wall / 1e3,       # KB/s
        "Total transferred": total_bytes,
        "Time taken for tests": wall,
    }


def run(report) -> None:
    with tempfile.TemporaryDirectory() as td:
        scenarios = {
            "hello_world": h_hello,
            "fibonacci": h_fibonacci,
            "file_retrieval": make_file_handler(Path(td)),
        }
        winners = {}
        for scen, handler in scenarios.items():
            meas = {c.name: {} for c in CRITERIA}
            for bk, fn in BACKENDS.items():
                fn(handler, 32, CONCURRENCY)             # warmup
                m = measure(fn, handler)
                for c in CRITERIA:
                    meas[c.name][bk] = m[c.name]
                report.row(f"framework/{scen}/{bk}/rps",
                           round(m["Requests per second"], 1), "req_per_s")
            res = run_ahp(list(BACKENDS), CRITERIA, meas)
            report.table(f"Backend AHP — {scen}", res.table())
            rank = res.ranking()
            winners[scen] = rank[0][0]
            report.row(f"framework/{scen}/winner", rank[0][0], "",
                       f"score={rank[0][1]*100:.1f}%")
        # paper-shape conclusion: minimal direct dispatch wins the
        # CPU-light scenario (its Falcon analogue)
        report.check("framework/hello_world_minimal_wins",
                     winners["hello_world"] == "direct",
                     f"winners={winners}")
