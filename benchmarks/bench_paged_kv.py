"""Paged KV block pool vs fixed stripes at EQUAL device KV memory —
plus the prefix-sharing scenario.

The fixed-stripe engine reserves a full ``max_seq`` stripe per slot, so
its concurrency is ``B = kv_tokens / max_seq`` no matter how short the
requests are. The paged engine spends the same token capacity as a
shared block pool; a request holds ``ceil(len / block_size)`` blocks, so
a mixed-length short-prompt workload packs many more requests into the
same memory. This bench serves one workload through both layouts and
reports the **max concurrent in-flight requests** each sustains — the
paged-KV headline number (checked >= 2x) — plus steps-to-drain,
decode-step latency, and the bit-exactness cross-check between layouts.

The ``--shared-prefix`` scenario (also part of the default run) serves
N requests with a common K-token prefix — the template-driven
extraction shape: same instruction preamble, different document tail —
through a sharing engine and a sharing-disabled one, and reports
**prefill tokens actually computed** (checked >= 2x fewer with sharing)
and **steady-state blocks used** (the shared prefix is resident once),
with the token streams checked identical.

    PYTHONPATH=src python -m benchmarks.bench_paged_kv [--shared-prefix]
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine

MAX_SEQ = 128          # stripe size of the fixed engine
FIXED_SLOTS = 4        # fixed engine: 4 x 128 = 512 KV token capacity
BLOCK = 16
NUM_BLOCKS = FIXED_SLOTS * (MAX_SEQ // BLOCK) + 1   # same 512 tokens + scratch
PAGED_SLOTS = 16       # slots are host bookkeeping; KV memory is the pool
N_REQS = 24
MAX_NEW = 8


def _workload(cfg, seed=0):
    lens = [(8, 24, 12, 40, 16, 8, 32, 12)[i % 8] for i in range(N_REQS)]
    rng = jax.random.key(seed)
    out = []
    for i, L in enumerate(lens):
        rng, k = jax.random.split(rng)
        out.append(Request(rid=i, max_new_tokens=MAX_NEW,
                           prompt=jax.random.randint(
                               k, (L,), 2, cfg.vocab_size).tolist()))
    return out


def _serve_tracking_peak(eng, reqs):
    """engine.run with peak-concurrency instrumentation."""
    pending = list(reqs)
    peak = steps = 0
    done = []
    while pending or eng.active or eng.waiting or eng._finished_at_admit:
        n = eng.add_requests(pending)
        del pending[:n]
        peak = max(peak, eng.active)
        done.extend(eng.step())
        steps += 1
    return peak, steps, done


def run(report) -> None:
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    kv_tokens = FIXED_SLOTS * MAX_SEQ
    assert (NUM_BLOCKS - 1) * BLOCK == kv_tokens    # equal-memory setup
    report.row("paged_kv.kv_token_capacity", kv_tokens, "tokens",
               "both layouts: identical device KV budget")

    fixed = ServingEngine(model, params, batch_size=FIXED_SLOTS,
                          max_seq=MAX_SEQ, paged=False)
    paged = ServingEngine(model, params, batch_size=PAGED_SLOTS,
                          max_seq=MAX_SEQ, paged=True, block_size=BLOCK,
                          num_blocks=NUM_BLOCKS)

    fixed_reqs = _workload(cfg)
    paged_reqs = _workload(cfg)
    fpeak, fsteps, _ = _serve_tracking_peak(fixed, fixed_reqs)
    ppeak, psteps, _ = _serve_tracking_peak(paged, paged_reqs)

    report.row("paged_kv.max_concurrent.fixed_stripe", fpeak, "requests",
               f"{FIXED_SLOTS} stripes x {MAX_SEQ}")
    report.row("paged_kv.max_concurrent.paged", ppeak, "requests",
               f"{NUM_BLOCKS - 1} blocks x {BLOCK}")
    ratio = ppeak / max(fpeak, 1)
    report.row("paged_kv.concurrency_ratio", round(ratio, 2), "x",
               "paged / fixed at equal KV memory")
    report.row("paged_kv.steps_to_drain.fixed_stripe", fsteps, "steps", "")
    report.row("paged_kv.steps_to_drain.paged", psteps, "steps",
               "fewer steps: more requests per decode batch")
    report.check("paged serves >= 2x concurrent requests at equal KV memory",
                 ratio >= 2.0, f"{ppeak} vs {fpeak} in flight ({ratio:.1f}x)")
    report.check("paged drains the workload in fewer decode steps",
                 psteps < fsteps, f"{psteps} vs {fsteps} steps")

    # ---------------------------------------------------- bit-exactness
    ok = all(a.out_tokens == b.out_tokens
             for a, b in zip(fixed_reqs, paged_reqs))
    report.check("paged token streams == fixed-stripe token streams", ok,
                 f"{N_REQS} requests compared")

    # ------------------------------------------------ decode-step latency
    for eng, tag, b in ((fixed, "fixed_stripe", FIXED_SLOTS),
                        (paged, "paged", FIXED_SLOTS)):
        reqs = [Request(rid=100 + i, prompt=list(r.prompt),
                        max_new_tokens=10 ** 6)
                for i, r in enumerate(_workload(cfg, seed=1)[:b])]
        assert eng.add_requests(reqs) == b

        def step():
            eng.step()
            jax.block_until_ready(eng.caches["k"])

        report.timeit(f"paged_kv.decode_step.{tag}.B{b}", step,
                      repeats=10, warmup=3,
                      derived=f"{b} active slots, mixed lengths")
        for slot, r in enumerate(list(eng.slot_req)):
            if r is not None:
                r.max_new_tokens = len(r.out_tokens)   # force retirement
        eng.step()

    # occupancy telemetry the scheduler sheds on
    report.row("paged_kv.pool_occupancy_after_drain",
               paged.pool_stats()["occupancy"], "frac",
               "all blocks returned")

    run_shared_prefix(report, model, params, cfg)


# ------------------------------------------------------- prefix sharing
N_SHARED = 8           # requests with a common prefix
PREFIX_LEN = 48        # the shared template prefix (3 x BLOCK)
SUFFIX_LEN = 4         # per-request distinct tail
SHARED_MAX_NEW = 6


def _shared_prefix_workload(cfg, seed=3):
    rng = jax.random.key(seed)
    rng, k = jax.random.split(rng)
    common = jax.random.randint(k, (PREFIX_LEN,), 2, cfg.vocab_size).tolist()
    out = []
    for i in range(N_SHARED):
        rng, k = jax.random.split(rng)
        sfx = jax.random.randint(k, (SUFFIX_LEN,), 2,
                                 cfg.vocab_size).tolist()
        out.append(Request(rid=i, prompt=common + sfx,
                           max_new_tokens=SHARED_MAX_NEW))
    return out


def _serve_tracking_blocks(eng, reqs):
    pending = list(reqs)
    peak_blocks = 0
    while pending or eng.active or eng.waiting or eng._finished_at_admit:
        n = eng.add_requests(pending)
        del pending[:n]
        peak_blocks = max(peak_blocks, eng.pool.used)
        eng.step()
    return peak_blocks


def run_shared_prefix(report, model=None, params=None, cfg=None) -> None:
    """N same-prefix requests through sharing vs no-sharing engines."""
    if model is None:
        cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                                  dtype=jnp.float32)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))

    engines = {
        name: ServingEngine(model, params, batch_size=N_SHARED,
                            max_seq=MAX_SEQ, paged=True, block_size=BLOCK,
                            prefix_sharing=share)
        for name, share in (("unshared", False), ("shared", True))
    }
    workloads = {name: _shared_prefix_workload(cfg) for name in engines}
    peaks = {name: _serve_tracking_blocks(eng, workloads[name])
             for name, eng in engines.items()}

    total_prompt = N_SHARED * (PREFIX_LEN + SUFFIX_LEN)
    report.row("paged_kv.shared_prefix.requests", N_SHARED, "requests",
               f"common {PREFIX_LEN}-token prefix + {SUFFIX_LEN}-token "
               "suffix each")
    computed = {name: eng.metrics["prefill_tokens_computed"]
                for name, eng in engines.items()}
    for name in engines:
        report.row(f"paged_kv.shared_prefix.prefill_tokens.{name}",
                   computed[name], "tokens",
                   f"of {total_prompt} total prompt tokens")
        report.row(f"paged_kv.shared_prefix.steady_state_blocks.{name}",
                   peaks[name], "blocks", "peak pool blocks in use")
    report.row("paged_kv.shared_prefix.tokens_reused",
               engines["shared"].metrics["prefill_tokens_shared"], "tokens",
               "prompt tokens served from resident blocks")
    ratio = computed["unshared"] / max(computed["shared"], 1)
    report.row("paged_kv.shared_prefix.prefill_reduction", round(ratio, 2),
               "x", "prefill tokens computed, unshared / shared")
    report.check("prefix sharing computes >= 2x fewer prefill tokens",
                 ratio >= 2.0,
                 f"{computed['unshared']} vs {computed['shared']} tokens "
                 f"({ratio:.1f}x)")
    report.check("prefix sharing uses fewer steady-state blocks",
                 peaks["shared"] < peaks["unshared"],
                 f"{peaks['shared']} vs {peaks['unshared']} peak blocks")
    ok = all(a.out_tokens == b.out_tokens
             for a, b in zip(workloads["shared"], workloads["unshared"]))
    report.check("shared-prefix token streams == unshared streams", ok,
                 f"{N_SHARED} requests compared")


if __name__ == "__main__":
    import argparse

    from benchmarks.report import Report

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run only the prefix-sharing scenario")
    args = ap.parse_args()
    rep = Report(verbose=True)
    if args.shared_prefix:
        run_shared_prefix(rep)
    else:
        run(rep)
    raise SystemExit(1 if rep.n_failed else 0)
