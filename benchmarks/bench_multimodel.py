"""TPU adaptation of the paper's parallel PaaS (DESIGN.md §3): mesh
space-sharing. Each model service owns a disjoint device group; one host
enqueues all services' steps (JAX async dispatch) and joins once.

On this 1-core container space-sharing degenerates to time-sharing, so
wall-clock parity (not speedup) is expected and asserted; the structural
claims — all services lower/compile on their sub-meshes, parallel and
sequential dispatch agree bitwise — are the validation. The speedup story
lives in the dry-run/roofline sections where device counts are real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.multimodel import ModelService, MultiModelServer

D = 128


def _mk_service(name: str, seed: int, layers: int = 2) -> ModelService:
    ks = jax.random.split(jax.random.key(seed), layers)
    params = [jax.random.normal(k, (D, D), jnp.float32) / np.sqrt(D)
              for k in ks]

    def step(params, batch):
        x = batch
        for w in params:
            x = jnp.tanh(x @ w)
        return x
    return ModelService(name, step, params)


def run(report) -> None:
    names = ["personal_information", "education", "work_experience",
             "skills", "functional_area"]
    services = [_mk_service(n, i) for i, n in enumerate(names)]
    server = MultiModelServer(services)

    batch = {n: jax.random.normal(jax.random.key(99), (8, D), jnp.float32)
             for n in names}

    # structural validation: every service lowers+compiles on its sub-mesh
    specs = {n: jax.ShapeDtypeStruct((8, D), jnp.float32) for n in names}
    compiled = server.lower_all(specs)
    report.check("multimodel/all_services_compile", len(compiled) == 5,
                 f"{len(compiled)}/5 compiled")

    server.serve_parallel(batch)            # warmup: compile + cache
    server.serve_sequential(batch)
    out_p, t_par = server.serve_parallel(batch)
    out_s, t_seq = server.serve_sequential(batch)
    agree = all(np.allclose(np.asarray(out_p[n]), np.asarray(out_s[n]))
                for n in names)
    report.check("multimodel/parallel_eq_sequential", agree, "bitwise join")
    report.row("multimodel/parallel_ms", round(t_par * 1e3, 2), "ms")
    report.row("multimodel/sequential_ms", round(t_seq * 1e3, 2), "ms")
    report.row("multimodel/speedup", round(t_seq / max(t_par, 1e-9), 2),
               "x", "1 device: parity expected (space->time sharing)")
