"""Benchmark harness — one module per paper table/figure (deliverable d).

    PYTHONPATH=src python -m benchmarks.run [--only SUBSTR] [--verbose]
        [--save DIR]

Output: ``name,value,unit,derived`` CSV rows + CHECK PASS/FAIL lines
validating the paper's claims. Exit code 1 if any check fails.

| module            | reproduces                                        |
|-------------------|---------------------------------------------------|
| bench_ahp         | Tables 3/4/5 (AHP on the paper's Table 2)         |
| bench_framework   | §3.1 methodology re-run on hostable backends      |
| bench_parallel    | Fig 8 / Table 6 (parallel vs sequential PaaS)     |
| bench_concurrency | Tables 7/8 (latency vs concurrency)               |
| bench_multimodel  | TPU adaptation: mesh space-sharing                |
| bench_kernels     | Pallas kernel correctness + analytic intensity    |
| bench_serving     | slot-native engine: device admission vs host copy |
|                   | + the paged default path end to end               |
| bench_paged_kv    | paged KV pool: concurrency at equal KV memory,    |
|                   | prefix sharing: prefill tokens actually computed  |
| bench_speculative | draft-and-verify decode: acceptance x draft       |
|                   | quality x k, tokens per target step               |
| bench_roofline    | §Roofline over the 40 dry-run artifacts           |
| bench_extraction  | end-to-end extraction quality (trains the stack)  |
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback
from pathlib import Path

MODULES = [
    "bench_ahp",
    "bench_framework",
    "bench_parallel",
    "bench_concurrency",
    "bench_multimodel",
    "bench_kernels",
    "bench_serving",
    "bench_paged_kv",
    "bench_speculative",
    "bench_roofline",
    "bench_extraction",     # trains the full stack: ~6 min on 1 core
]


def select_modules(only: str) -> list[str]:
    """Resolve a ``--only`` comma-filter against MODULES. Every filter
    must match at least one module — a typo ("pagedkv") used to silently
    run *nothing* and exit 0, which in CI reads as a green bench run."""
    filters = [f for f in only.split(",") if f]
    if not filters:
        return list(MODULES)
    for f in filters:
        if not any(f in name for name in MODULES):
            raise SystemExit(
                f"--only filter {f!r} matches no benchmark module; "
                f"choose from: {', '.join(MODULES)}")
    return [name for name in MODULES
            if any(f in name for f in filters)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="substring filter; comma-separate to run "
                         "several (e.g. --only paged_kv,serving)")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--save", default="experiments/bench",
                    help="directory for results.csv/tables.md ('' = off)")
    args = ap.parse_args()

    from benchmarks.report import Report
    report = Report(verbose=args.verbose)
    failed_modules = []
    for name in select_modules(args.only):
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(report)
        except Exception:  # noqa: BLE001 — keep the suite going
            failed_modules.append(name)
            traceback.print_exc()
        print(f"----- {name} done in {time.time() - t0:.1f}s", flush=True)

    if args.save:
        report.save(Path(args.save))
    n_checks = len(report.checks)
    print(f"\n{n_checks} checks, {report.n_failed} failed; "
          f"{len(report.rows)} rows; crashed modules: {failed_modules}")
    if report.n_failed or failed_modules:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
