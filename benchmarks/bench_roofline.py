"""Roofline report over the 40 (arch x shape) dry-run artifacts
(deliverable g) — single-pod mesh, per the assignment.

Emits the three roofline terms + dominant bottleneck per pair, checks
HBM fit (peak bytes/device <= 16 GiB on v5e), and verifies all 40
single-pod + 40 multi-pod artifacts exist and compiled OK.
"""
from __future__ import annotations

import json

from repro.configs.base import ARCH_IDS
from repro.configs.shapes import SHAPES
from repro.launch import roofline

HBM_GIB = 16.0            # v5e HBM per chip


def run(report) -> None:
    for mesh in ("single", "multi"):
        recs = {}
        for p in sorted(roofline.RESULTS_DIR.glob(f"*__{mesh}.json")):
            rec = json.loads(p.read_text())
            recs[(rec["arch"], rec["shape"])] = rec
        expected = {(a, s) for a in ARCH_IDS for s in SHAPES}
        ok = [k for k in expected
              if recs.get(k, {}).get("status") == "ok"]
        report.check(f"dryrun/{mesh}/all_40_compile", len(ok) == 40,
                     f"{len(ok)}/40 ok; missing/failed: "
                     f"{sorted(expected - set(ok))[:4]}")

    rows = roofline.load_all("single")
    report.table("Roofline — single pod (16x16, 256 chips)",
                 roofline.markdown_table(rows))

    over, infeasible, pod_sizing = [], [], []
    by_dom = {"compute": 0, "memory": 0, "collective": 0}
    for r in rows:
        by_dom[r.dominant] += 1
        report.row(f"roofline/{r.arch}/{r.shape}/dominant", r.dominant, "",
                   f"c={r.compute_s:.2e}s m={r.memory_s:.2e}s "
                   f"coll={r.collective_s:.2e}s peak={r.peak_gib:.2f}GiB "
                   f"useful={r.useful_flops_ratio:.2f}")
        if r.peak_gib <= HBM_GIB:
            continue
        weights_gib = 2.0 * r.n_params / r.n_devices / 2**30
        if not r.feasible(HBM_GIB):
            # weights+optimizer alone exceed HBM: not a sharding defect
            infeasible.append((r.arch, r.shape))
            report.row(f"roofline/{r.arch}/{r.shape}/CAPACITY_INFEASIBLE",
                       round(r.static_gib, 2), "GiB",
                       f"static (ideal) > {HBM_GIB} GiB; needs more chips")
        elif weights_gib > 2.0:
            # >=~270B params on this mesh: weights alone eat the
            # activation headroom — the pair sizes the pod, the dry-run
            # proves the sharding; multi-pod runs of the same config
            # show the scaling (EXPERIMENTS.md §Roofline)
            pod_sizing.append((r.arch, r.shape, round(r.peak_gib, 2)))
            report.row(f"roofline/{r.arch}/{r.shape}/POD_SIZING",
                       round(r.peak_gib, 2), "GiB",
                       f"weights {weights_gib:.1f} GiB/chip; needs >1 pod "
                       f"at this batch")
        else:
            over.append((r.arch, r.shape, round(r.peak_gib, 2)))
            report.row(f"roofline/{r.arch}/{r.shape}/OVER_HBM",
                       round(r.peak_gib, 2), "GiB", f"> {HBM_GIB} GiB")
    report.check("roofline/no_sharding_defect_over_hbm", not over,
                 f"over-HBM (sharding defects): {over}; pod-sizing-limited "
                 f"(>=270B-param, documented): {pod_sizing}; "
                 f"capacity-infeasible (documented): {infeasible}")
    report.row("roofline/dominant_histogram", "", "",
               " ".join(f"{k}:{v}" for k, v in by_dom.items()))

    # -------------------------------------------------- multi-pod scaling
    multi = {(r.arch, r.shape): r for r in roofline.load_all("multi")}
    lines = ["arch | shape | peak 256 (GiB) | peak 512 | compute 256->512 "
             "| dominant 512", " | ".join(["---"] * 6)]
    n_better = n_pairs = 0
    for r in rows:
        m = multi.get((r.arch, r.shape))
        if m is None:
            continue
        n_pairs += 1
        n_better += m.peak_gib <= r.peak_gib * 1.05
        if r.arch in ("nemotron-4-340b", "kimi-k2-1t-a32b", "grok-1-314b"):
            lines.append(
                f"{r.arch} | {r.shape} | {r.peak_gib:.1f} | {m.peak_gib:.1f}"
                f" | {r.compute_s:.2e} -> {m.compute_s:.2e} | {m.dominant}")
    report.table("Multi-pod scaling (big models, 256 -> 512 chips)",
                 "\n".join(lines))
    report.check("roofline/multipod_peak_not_worse",
                 n_better >= 0.8 * n_pairs,
                 f"{n_better}/{n_pairs} pairs peak <= single-pod x1.05")
