"""End-to-end extraction quality — the paper's functional claim.

The paper's pipeline exists to turn a CV into structured fields; its
quality numbers live on a proprietary 50k-resume corpus (repro band 2:
data gate), so this benchmark trains the full stack on the synthetic
corpus and measures what the paper could not publish:

  * sectioning accuracy of the BERT-encoder + 154,604-param classifier
    (paper §3.2.2) on held-out documents,
  * end-to-end entity F1 of the parallel-PaaS parser (trained NERs
    behind the router) against the corpus's gold token labels.

Checks: sectioning accuracy > 0.9, micro-F1 > 0.75 on held-out CVs —
i.e. the deployed architecture actually parses, it doesn't just meet
latency SLOs. (Measured: sectioning 1.00, F1 0.80 at 120 NER steps.)
"""
from __future__ import annotations

import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cvdata, router
from repro.core.cvdata import SERVICE_LABELS, HashTokenizer
from repro.core.pipeline import MAX_SENT_LEN, CVParser, NERModel
from repro.models import bert_encoder, bilstm_lan
from repro.train import optimizer as opt

VOCAB = 4096
N_TRAIN_DOCS = 160
N_TEST_DOCS = 40
NER_STEPS = 120
CLF_STEPS = 150


def _train_ner(name: str, sents, rng):
    labels = SERVICE_LABELS[name]
    ner = NERModel.create(name, rng, VOCAB)
    tok = ner.tokenizer
    X = np.array([tok.pad(tok.encode(s.tokens), MAX_SENT_LEN)
                  for s in sents], np.int32)
    Y = np.zeros((len(sents), MAX_SENT_LEN), np.int32)
    for i, s in enumerate(sents):
        for j, lab in enumerate(s.labels[:MAX_SENT_LEN]):
            Y[i, j] = labels.index(lab) if lab in labels else 0
    M = (X != 0).astype(np.float32)

    c = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=NER_STEPS,
                        weight_decay=0.0)
    state = opt.init_state(ner.params)
    params = ner.params

    @jax.jit
    def step(params, state):
        _, g = jax.value_and_grad(
            lambda p: bilstm_lan.loss(p, ner.cfg, X, Y, M))(params)
        params, state, _ = opt.apply_updates(params, g, state, c)
        return params, state

    for _ in range(NER_STEPS):
        params, state = step(params, state)
    ner.params = params
    return ner


def _train_classifier(parser, docs):
    """Train the Dense(768->200->4) sectioning head on frozen encoder
    embeddings (the paper trains exactly this head)."""
    tok = parser.tokenizer
    X, y = [], []
    for d in docs:
        for s in d.sentences:
            X.append(tok.pad(tok.encode(s.tokens), MAX_SENT_LEN))
            y.append(router.SECTION_CLASSES[s.section])
    X = jnp.asarray(np.array(X, np.int32))
    y = jnp.asarray(np.array(y, np.int32))
    emb = parser._embed(parser.encoder_params, X, X != 0)

    c = opt.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=CLF_STEPS,
                        weight_decay=0.0)
    params = parser.classifier_params
    state = opt.init_state(params)

    def loss_fn(p):
        logits = bert_encoder.classify_sections(p, emb)
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))

    @jax.jit
    def step(params, state):
        _, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.apply_updates(params, g, state, c)
        return params, state

    for _ in range(CLF_STEPS):
        params, state = step(params, state)
    parser.classifier_params = params


def run(report) -> None:
    rng = random.Random(11)
    train_docs = [cvdata.make_document(rng) for _ in range(N_TRAIN_DOCS)]
    test_docs = [cvdata.make_document(rng) for _ in range(N_TEST_DOCS)]

    # ---- train the five section NERs on routed training sentences
    keys = jax.random.split(jax.random.key(5), len(router.ROUTES))
    parser = CVParser.create(jax.random.key(0), vocab_size=VOCAB)
    for (name, sections), k in zip(router.ROUTES.items(), keys):
        sents = [s for d in train_docs for s in d.sentences
                 if s.section in sections]
        ner = _train_ner(name, sents, k)
        parser.services[name].replicas[0].handler = ner

    # ---- train the sectioning classifier
    _train_classifier(parser, train_docs)

    # ---- held-out sectioning accuracy
    tok = parser.tokenizer
    X, y = [], []
    for d in test_docs:
        for s in d.sentences:
            X.append(tok.pad(tok.encode(s.tokens), MAX_SENT_LEN))
            y.append(router.SECTION_CLASSES[s.section])
    X = jnp.asarray(np.array(X, np.int32))
    emb = parser._embed(parser.encoder_params, X, X != 0)
    pred = np.asarray(jnp.argmax(
        bert_encoder.classify_sections(parser.classifier_params, emb), -1))
    sec_acc = float((pred == np.array(y)).mean())
    report.row("extraction/sectioning_accuracy", round(sec_acc, 4), "",
               f"{len(y)} held-out sentences")
    report.check("extraction/sectioning_acc>0.9", sec_acc > 0.9,
                 f"{sec_acc:.3f}")

    # ---- end-to-end F1 through the full parallel pipeline
    tp = fp = fn = 0
    for d in test_docs:
        out = parser.parse(d)
        pred_fields = {(svc, t, lab) for svc, ents in out["fields"].items()
                       for t, lab in ents}
        gold = set()
        for s in d.sentences:
            for svc, sections in router.ROUTES.items():
                if s.section in sections:
                    svc_labels = SERVICE_LABELS[svc]
                    for t, lab in zip(s.tokens[:MAX_SENT_LEN],
                                      s.labels[:MAX_SENT_LEN]):
                        if lab != "O" and lab in svc_labels:
                            gold.add((svc, t, lab))
        tp += len(pred_fields & gold)
        fp += len(pred_fields - gold)
        fn += len(gold - pred_fields)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    report.row("extraction/e2e_precision", round(prec, 4), "")
    report.row("extraction/e2e_recall", round(rec, 4), "")
    report.row("extraction/e2e_micro_f1", round(f1, 4), "",
               f"{N_TEST_DOCS} held-out CVs through the parallel pipeline")
    report.check("extraction/e2e_f1>0.75", f1 > 0.75,
                 f"P={prec:.3f} R={rec:.3f} F1={f1:.3f}")
