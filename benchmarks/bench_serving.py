"""Serving engine hot path: device-side admission vs the legacy host-copy
path, and mixed-length decode-step latency.

The seed engine admitted a request by copying the ENTIRE KV cache to
host numpy, splicing the prefill result in, and shipping it back —
O(L x B x max_seq) bytes over PCIe per admission. The slot-native engine
prefills a batch of waiting requests in one jitted call whose
``dynamic_update_slice`` writes each sequence's KV straight into its
slot on device. This bench times both against identical request mixes
and checks the device path wins at batch >= 4 (acceptance criterion),
plus reports per-step decode latency with all slots at different
lengths (the mixed-length continuous-batching configuration).

The stripe scenarios isolate the admission comparison; the **paged**
scenarios then time the default engine configuration (block-pool
admission through retire, and block-table decode steps), so the
flagship path is benchmarked, not just the legacy one. The **chunked
prefill** scenario then measures the responsiveness headline: the
decode stall a long-prompt arrival causes mid-flight, monolithic vs
decode-interleaved chunk ingestion (checked: chunking cuts the worst
stall, streams identical).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServingEngine

MAX_SEQ = 128


class _LegacyHostCopyAdmission:
    """The seed engine's admission path, kept verbatim for the before
    side of the comparison: full host round-trip of every cache leaf."""

    def __init__(self, model, params, batch_size, max_seq):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.caches = model.init_cache(batch_size, max_seq)
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, None))

    def add(self, slot: int, prompt: list) -> int:
        toks = jnp.asarray([prompt], jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": toks})
        for key in self.caches:
            c = np.array(self.caches[key])          # writable host copy
            pref = np.asarray(cache[key])
            if c.ndim >= 3 and pref.ndim == c.ndim and \
                    c.shape[2] == self.max_seq and pref.shape[2] <= self.max_seq:
                c[:, slot] = 0
                c[:, slot, :pref.shape[2]] = pref[:, 0]
            else:
                c[:, slot] = pref[:, 0]
            self.caches[key] = jnp.asarray(c)
        return int(jnp.argmax(logits[0, -1]))


def _prompts(cfg, lens, seed=0):
    rng = jax.random.key(seed)
    out = []
    for L in lens:
        rng, k = jax.random.split(rng)
        out.append(jax.random.randint(k, (L,), 2, cfg.vocab_size).tolist())
    return out


def run(report) -> None:
    cfg = dataclasses.replace(get_config("qwen3-4b").reduced(),
                              dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    results = {}
    for B in (2, 4, 8):
        lens = [5 + 3 * (i % 4) for i in range(B)]   # mixed lengths
        prompts = _prompts(cfg, lens)

        # paged=False here: this scenario measures the STRIPE admission
        # path against the seed's host-copy (and resets slots by hand,
        # which would leak pool blocks); the paged scenarios below and
        # bench_paged_kv cover the pool.
        eng = ServingEngine(model, params, batch_size=B, max_seq=MAX_SEQ,
                            paged=False)

        def admit_device():
            reqs = [Request(rid=i, prompt=list(p), max_new_tokens=1)
                    for i, p in enumerate(prompts)]
            eng.slot_req = [None] * B                # recycle all slots
            eng.slot_len[:] = 0
            eng._finished_at_admit.clear()
            assert eng.add_requests(reqs) == B
            jax.block_until_ready(eng.caches["k"])

        legacy = _LegacyHostCopyAdmission(model, params, B, MAX_SEQ)

        def admit_host_copy():
            for slot, p in enumerate(prompts):
                legacy.add(slot, p)
            jax.block_until_ready(legacy.caches["k"])

        dev = report.timeit(f"serving.admit.device.B{B}", admit_device,
                            repeats=7, warmup=2,
                            derived=f"{B} mixed-length prompts / batch")
        host = report.timeit(f"serving.admit.host_copy.B{B}", admit_host_copy,
                             repeats=7, warmup=2,
                             derived="seed engine: full-cache np round-trip")
        results[B] = (dev, host)
        report.row(f"serving.admit.speedup.B{B}", round(host / dev, 2), "x",
                   "host_copy / device")

        # ------------------------------ decode-step latency, mixed lengths
        eng2 = ServingEngine(model, params, batch_size=B, max_seq=MAX_SEQ,
                             paged=False)
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=10 ** 6)
                for i, p in enumerate(prompts)]
        assert eng2.add_requests(reqs) == B

        def decode_step():
            if max(eng2.slot_len) >= MAX_SEQ - 1:    # paranoia: never hit
                raise RuntimeError("capacity")
            eng2.step()
            jax.block_until_ready(eng2.caches["k"])

        report.timeit(f"serving.decode_step.B{B}", decode_step,
                      repeats=10, warmup=3,
                      derived="per-slot lengths, all slots active")

    for B in (4, 8):
        dev, host = results[B]
        report.check(f"device admission faster at B={B}", dev < host,
                     f"device {dev*1e3:.1f}ms vs host-copy {host*1e3:.1f}ms")

    # ------------------------- paged-path scenarios (the default config)
    # The stripe timings above isolate the device-vs-host admission win
    # (and hand-reset slots, which would leak pool blocks); the flagship
    # engine configuration is PAGED — time it too, end to end, so the
    # default path the tests enforce is also the path the bench watches.
    for B in (4, 8):
        lens = [5 + 3 * (i % 4) for i in range(B)]
        prompts = _prompts(cfg, lens, seed=2)
        eng = ServingEngine(model, params, batch_size=B, max_seq=MAX_SEQ,
                            paged=True, block_size=16,
                            prefix_sharing=False)   # time the compute path

        def admit_paged():
            reqs = [Request(rid=i, prompt=list(p), max_new_tokens=1)
                    for i, p in enumerate(prompts)]
            done = eng.run(reqs)         # admit, emit, retire: blocks freed
            assert len(done) == B
            jax.block_until_ready(eng.caches["k"])

        report.timeit(f"serving.admit.paged.B{B}", admit_paged,
                      repeats=7, warmup=2,
                      derived=f"{B} prompts through the block pool, "
                      "admit->retire")
        report.check(f"paged admission drains the pool clean at B={B}",
                     eng.pool.available == eng.pool.total,
                     f"{eng.pool.available}/{eng.pool.total} blocks free")

        eng2 = ServingEngine(model, params, batch_size=B, max_seq=MAX_SEQ,
                             paged=True, block_size=16)
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=10 ** 6)
                for i, p in enumerate(prompts)]
        assert eng2.add_requests(reqs) == B

        def decode_step_paged():
            if max(eng2.slot_len) >= MAX_SEQ - 1:    # paranoia: never hit
                raise RuntimeError("capacity")
            eng2.step()
            jax.block_until_ready(eng2.caches["k"])

        report.timeit(f"serving.decode_step.paged.B{B}", decode_step_paged,
                      repeats=10, warmup=3,
                      derived="block-table gather/scatter decode, "
                      "mixed lengths")

    # mixed-length equivalence spot check rides along with the bench —
    # on the DEFAULT engine (paged for this pure-attention family)
    lens = [5, 9, 12, 7]
    eng = ServingEngine(model, params, batch_size=4, max_seq=MAX_SEQ)
    assert eng.paged                       # default config is the pool
    solo = ServingEngine(model, params, batch_size=1, max_seq=MAX_SEQ)
    batched = [Request(rid=i, prompt=list(p), max_new_tokens=4)
               for i, p in enumerate(_prompts(cfg, lens, seed=3))]
    done = eng.run(list(batched))
    ok = True
    for r in batched:
        (d,) = solo.run([Request(rid=100 + r.rid, prompt=list(r.prompt),
                                 max_new_tokens=4)])
        ok &= d.out_tokens == r.out_tokens
    report.check("mixed-length batch == sequential outputs",
                 ok and len(done) == 4, f"{len(done)}/4 equal token streams")

    run_chunked_prefill(report, model, params, cfg)
    run_open_loop(report, model, params, cfg)
    run_tracer_overhead(report, model, params, cfg)


# ------------------------------------------------- telemetry overhead gate
TRACE_REPS = 5          # interleaved A/B repeats per side, median taken
TRACE_REL = 0.02        # enabled tracer: < 2% on the closed-loop serve
TRACE_ABS_S = 1e-3      # plus 1ms absolute slack: a single scheduler
#                         hiccup on a shared CI host must not fail a gate
#                         about nanosecond-scale emission costs
NOOP_REL = 0.005        # no-op path: < 0.5% (derived bound, see below)


def run_tracer_overhead(report, model, params, cfg) -> None:
    """The overhead contract from docs/observability.md, enforced:
    serving with a recording :class:`Tracer` stays within 2% of the
    default no-op path on the closed-loop admit->retire scenario, and
    the no-op path's own cost stays under 0.5%.

    The enabled gate interleaves A/B serves (noop, traced, noop,
    traced, ...) and compares medians, so drift on a shared host hits
    both sides alike. The no-op gate is DERIVED rather than differenced:
    two identical engines differ only by noise, so instead the guard
    cost is micro-benchmarked (``tracer.enabled`` check + early return)
    and multiplied by the emission-site count one serve actually fires
    (the traced run's event count) — that product must be under 0.5% of
    the serve time. A differenced 0.5% gate would be a coin flip in CI;
    the derived bound fails only if the no-op path grows real work."""
    from repro.serve.telemetry import NOOP, Tracer

    lens = [5 + 3 * (i % 4) for i in range(4)]
    prompts = _prompts(cfg, lens, seed=7)

    def build(tracer):
        return ServingEngine(model, params, batch_size=4, max_seq=MAX_SEQ,
                             paged=True, block_size=16,
                             prefix_sharing=False, tracer=tracer)

    tracer = Tracer()
    eng_noop = build(None)          # default: the NOOP singleton
    eng_traced = build(tracer)

    def serve(eng, base_rid):
        reqs = [Request(rid=base_rid + i, prompt=list(p),
                        max_new_tokens=8) for i, p in enumerate(prompts)]
        done = eng.run(reqs)
        assert len(done) == 4
        jax.block_until_ready(eng.caches["k"])

    # warmup both (each engine owns its jitted closures)
    serve(eng_noop, 0)
    serve(eng_traced, 0)
    ev0 = len(tracer)
    serve(eng_traced, 0)
    events_per_serve = len(tracer) - ev0

    noop_t, traced_t = [], []
    for rep in range(TRACE_REPS):
        t0 = time.perf_counter()
        serve(eng_noop, 1000 * (rep + 1))
        noop_t.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        serve(eng_traced, 1000 * (rep + 1))
        traced_t.append(time.perf_counter() - t0)
    noop_med = sorted(noop_t)[TRACE_REPS // 2]
    traced_med = sorted(traced_t)[TRACE_REPS // 2]

    report.row("serving.telemetry.serve_noop", round(noop_med * 1e3, 2),
               "ms", "closed-loop serve, default no-op tracer")
    report.row("serving.telemetry.serve_traced", round(traced_med * 1e3, 2),
               "ms", f"same serve recording {events_per_serve} events")
    report.row("serving.telemetry.overhead",
               round((traced_med / noop_med - 1) * 100, 2), "%",
               "traced / noop - 1 (median of interleaved repeats)")
    report.check("tracer overhead < 2% on closed-loop serve",
                 traced_med <= noop_med * (1 + TRACE_REL) + TRACE_ABS_S,
                 f"traced {traced_med*1e3:.2f}ms vs noop "
                 f"{noop_med*1e3:.2f}ms (+1ms slack), "
                 f"{events_per_serve} events/serve")

    # guard cost: what every emission site pays when tracing is off
    N = 200_000
    t0 = time.perf_counter()
    for _ in range(N):
        if NOOP.enabled:
            NOOP.instant("x", pid=0)
    guard_s = (time.perf_counter() - t0) / N
    noop_cost = guard_s * events_per_serve
    report.row("serving.telemetry.noop_guard", round(guard_s * 1e9, 1),
               "ns", "per emission site, tracing off")
    report.check("no-op path < 0.5% of serve time",
                 noop_cost < noop_med * NOOP_REL,
                 f"{events_per_serve} sites x {guard_s*1e9:.0f}ns = "
                 f"{noop_cost*1e6:.1f}us vs 0.5% of "
                 f"{noop_med*1e3:.2f}ms serve")


# ------------------------------------------- chunked prefill vs monolithic
CHUNK_MAX_SEQ = 512
LONG_PROMPT = 384      # the "full CV" arriving mid-decode: prefill at the
#                        512 bucket is ~16x a 32-token chunk window's work,
#                        so the stall signal clears CI host noise
CHUNK = 32
RIDER_NEW = 24         # decode steps the riders are mid-flight for


def _event_prefill_tokens(eng):
    """Prompt tokens actually run through prefill/window compute so far:
    the admission counter charges a chunked prompt up front, so the
    still-pending queue is subtracted to attribute work to the event
    that computes it."""
    return eng.metrics["prefill_tokens_computed"] \
        - sum(len(p) for p in eng.slot_pending)


def run_chunked_prefill(report, model, params, cfg) -> None:
    """Decode responsiveness under concurrent long-prompt arrival — the
    paper's headline scenario (a full document parsed while a sequential
    flow of requests keeps being served). Three short requests decode;
    a LONG prompt arrives mid-flight. Monolithic prefill stalls every
    in-flight slot for the whole prompt inside one admission call;
    chunked prefill admits it as budgeted chunk windows interleaved with
    the riders' decode steps. Reported: the worst single serve-loop
    event (the decode stall the arrival causes) and the p99 over all
    events after the arrival — plus the stream-identity cross-check."""
    results = {}
    streams = {}
    for mode, chunk in (("monolithic", 0), ("chunked", CHUNK)):
        eng = ServingEngine(
            model, params, batch_size=4, max_seq=CHUNK_MAX_SEQ,
            paged=True, block_size=16,
            num_blocks=4 * (CHUNK_MAX_SEQ // 16) + 1,
            prefix_sharing=False, prefill_chunk=chunk)

        def workload(base_rid):
            riders = [Request(rid=base_rid + i, prompt=list(p),
                              max_new_tokens=RIDER_NEW)
                      for i, p in enumerate(_prompts(cfg, [7, 12, 9],
                                                     seed=4))]
            (lp,) = _prompts(cfg, [LONG_PROMPT], seed=5)
            long_req = Request(rid=base_rid + 9, prompt=list(lp),
                               max_new_tokens=4)
            return riders, long_req

        def serve(riders, long_req, events):
            assert eng.add_requests(riders) == 3
            for _ in range(3):                     # riders mid-decode
                eng.step()
            pending = [long_req]
            done = []
            while pending or eng.active or eng.waiting \
                    or eng._finished_at_admit:
                t0 = time.perf_counter()
                w0 = _event_prefill_tokens(eng)
                n = eng.add_requests(pending)
                del pending[:n]
                done.extend(eng.step())
                jax.block_until_ready(eng.caches["k"])
                events.append((time.perf_counter() - t0,
                               _event_prefill_tokens(eng) - w0))
            return done

        # warmup on the SAME engine (each engine owns its jitted
        # closures, so a fresh engine would recompile) — the drained
        # pool and freed slots make it reusable. Median of 3 measured
        # serves for the wall-clock rows; the regression CHECK gates on
        # the DETERMINISTIC per-event prefill-token bound (wall time on
        # a shared CI host is too noisy to gate a merge on).
        serve(*workload(0), events=[])
        stalls, p50s, tok_max = [], [], 0
        for rep in range(3):
            events: list = []
            riders, long_req = workload(100 * (rep + 1))
            done = serve(riders, long_req, events)
            assert len(done) == 4
            walls = sorted(w for w, _ in events)
            stalls.append(walls[-1])
            tok_max = max(tok_max, max(t for _, t in events))
            p50s.append(walls[len(walls) // 2])
        results[mode] = (sorted(stalls)[1], tok_max, sorted(p50s)[1])
        streams[mode] = [r.out_tokens for r in riders + [long_req]]
        stall, tok_max, p50 = results[mode]
        report.row(f"serving.chunked.{mode}.max_stall", round(stall * 1e3, 2),
                   "ms", f"worst serve-loop event, {LONG_PROMPT}-token "
                   "arrival mid-decode (median of 3 serves; the empirical "
                   "p99 tail at ~20 events/serve)")
        report.row(f"serving.chunked.{mode}.max_event_prefill_tokens",
                   tok_max, "tokens",
                   "prompt tokens the worst single event ran through "
                   "prefill/window compute")
        report.row(f"serving.chunked.{mode}.p50_step", round(p50 * 1e3, 2),
                   "ms", "median serve-loop event after arrival")
        report.row(f"serving.chunked.{mode}.events", len(events), "steps", "")
    ratio = results["monolithic"][0] / max(results["chunked"][0], 1e-9)
    report.row("serving.chunked.stall_reduction", round(ratio, 2), "x",
               "monolithic max stall / chunked max stall (wall, "
               "informational)")
    # deterministic gate: interleaving must bound every event's prefill
    # work at 2 chunks (a serve event is add_requests + one step, so the
    # arrival event runs the admission chunk plus one chunk window),
    # where the monolithic arrival runs the whole prompt in one event —
    # if chunking silently degrades to a monolithic stall, this fails
    # regardless of host timing noise
    report.check("chunked prefill bounds per-event prompt work at 2 chunks",
                 results["chunked"][1] <= 2 * CHUNK
                 and results["monolithic"][1] >= LONG_PROMPT,
                 f"worst event ran {results['chunked'][1]} prompt tokens "
                 f"chunked vs {results['monolithic'][1]} monolithic")
    # the wall-clock stall comparison is deliberately a ROW, not a CHECK:
    # chunked serves sample ~2x more events than monolithic, so a single
    # scheduler hiccup on a shared CI host can land the chunked max above
    # the monolithic one regardless of the real signal (measured 2-3.4x
    # reduction on an idle host — the trajectory rows carry it)
    report.check("chunked streams == monolithic streams",
                 streams["chunked"] == streams["monolithic"],
                 "4 requests compared (3 riders + the long arrival)")


# ------------------------------------------------- open-loop Poisson serving
OPEN_LOOP_N = 16       # arrivals
OPEN_LOOP_RATE = 0.45  # mean arrivals per serve-loop tick
OPEN_LOOP_MAX_NEW = 8
OPEN_LOOP_BLOCKS = 6   # tight pool: admission gates on blocks at peaks
#                        (queue heads wait with a slot free), so arrivals
#                        actually queue across plan windows instead of
#                        admitting the tick they land
# deterministic bound on time-to-first-token, in serve-loop ticks: the
# arrival trace, engine outputs, and scheduling are all tick-exact
# (seeded Poisson, no wall time), so p99 is one number on every host.
# Measured 7 ticks with B=4 at rate 0.45 over the block-gated pool; 16
# leaves headroom for scheduler-policy evolution without hiding a
# pipeline stall (a serialized or livelocked loop blows far past it).
OPEN_LOOP_TTFT_P99_TICKS = 16


def run_open_loop(report, model, params, cfg) -> None:
    """Open-loop arrivals against the async dispatch -> plan-ahead ->
    commit serve loop: requests arrive on a seeded Poisson schedule in
    the tick domain (closed-loop drains hide queueing delay: the paper's
    production traffic does not wait for the previous batch). Gated
    deterministically: streamed tokens bit-identical to a synchronous
    drain of the same requests, TTFT p99 in ticks under a fixed bound,
    first token strictly before completion, and the overlap window doing
    real work (admission costs planned while the device step is in
    flight, later fills consuming the cache). Wall-clock TTFT and the
    plan-vs-commit time split are reported as rows."""
    from repro.serve.async_loop import AsyncServeLoop
    from repro.serve.scheduler import Scheduler

    arr_rng = np.random.default_rng(11)
    gaps = arr_rng.exponential(1.0 / OPEN_LOOP_RATE, OPEN_LOOP_N)
    arrival = np.floor(np.cumsum(gaps)).astype(int).tolist()
    lens = [5 + int(x) for x in arr_rng.integers(0, 8, OPEN_LOOP_N)]
    prompts = _prompts(cfg, lens, seed=6)

    def build():
        return ServingEngine(model, params, batch_size=4, max_seq=MAX_SEQ,
                             paged=True, block_size=16,
                             num_blocks=OPEN_LOOP_BLOCKS,
                             prefix_sharing=False)

    eng = build()
    sched = Scheduler(eng)
    loop = AsyncServeLoop(sched, name="open-loop")
    streams: dict = {i: [] for i in range(OPEN_LOOP_N)}
    first_tick: dict = {}
    done_tick: dict = {}
    first_wall: dict = {}
    wall_t0: dict = {}
    handles: dict = {}
    t = 0
    nxt = 0
    while nxt < OPEN_LOOP_N or any(not h.done for h in handles.values()):
        while nxt < OPEN_LOOP_N and arrival[nxt] <= t:
            rid = nxt

            def tap(tok, logp, rid=rid):
                if rid not in first_tick:
                    first_tick[rid] = t          # current pump iteration
                    first_wall[rid] = time.perf_counter() - wall_t0[rid]
                streams[rid].append(tok)

            wall_t0[rid] = time.perf_counter()
            handles[rid] = loop.submit(
                Request(rid=rid, prompt=list(prompts[rid]),
                        max_new_tokens=OPEN_LOOP_MAX_NEW), tap)
            nxt += 1
        loop.run_once()
        for rid, h in handles.items():
            if h.done and rid not in done_tick:
                done_tick[rid] = t
        t += 1
        assert t < 10_000, "open-loop serve did not drain"

    # --- bit-identity vs the synchronous tick drain ------------------
    ref = build()
    ref_done = ref.run([Request(rid=100 + i, prompt=list(prompts[i]),
                                max_new_tokens=OPEN_LOOP_MAX_NEW)
                        for i in range(OPEN_LOOP_N)])
    ref_streams = {r.rid - 100: r.out_tokens for r in ref_done}
    report.check("open-loop async streams == synchronous drain",
                 streams == ref_streams,
                 f"{OPEN_LOOP_N} Poisson arrivals vs closed-loop engine "
                 f"run, token-exact")
    eng.pool.check()                       # raises on invariant breach
    report.check("open-loop pool drains clean",
                 eng.pool.available == eng.pool.total,
                 f"{eng.pool.available}/{eng.pool.total} blocks free")

    # --- responsiveness gates (tick domain: deterministic) -----------
    ttft = sorted(first_tick[r] - arrival[r] for r in range(OPEN_LOOP_N))
    p99 = ttft[min(int(0.99 * len(ttft)), len(ttft) - 1)]
    report.row("serving.open_loop.ttft_p50", ttft[len(ttft) // 2], "ticks",
               f"rate {OPEN_LOOP_RATE}/tick, B=4, "
               f"{OPEN_LOOP_BLOCKS}-block pool")
    report.row("serving.open_loop.ttft_p99", p99, "ticks", "deterministic")
    report.check("open-loop TTFT p99 within bound",
                 p99 <= OPEN_LOOP_TTFT_P99_TICKS,
                 f"p99 {p99} ticks <= {OPEN_LOOP_TTFT_P99_TICKS}")
    report.check("first token streams before completion",
                 all(first_tick[r] < done_tick[r]
                     for r in range(OPEN_LOOP_N)),
                 "every request observed a token mid-flight, none only "
                 "at completion")

    # --- overlap gates: the plan window does real, consumed work -----
    m = loop.metrics
    report.check("plan-ahead runs inside the dispatch->commit window",
                 m["planned_ahead_ticks"] > 0 and m["planned"] > 0,
                 f"{m['planned']} admission costs planned across "
                 f"{m['planned_ahead_ticks']} in-flight windows")
    report.check("fills consume plan-ahead results",
                 sched.stats.plan_hits > 0,
                 f"{sched.stats.plan_hits} admissions served from the "
                 f"plan cache (validity stamp unchanged since planning)")
    if first_wall:
        walls = sorted(first_wall.values())
        report.row("serving.open_loop.ttft_wall_p50",
                   round(walls[len(walls) // 2] * 1e3, 2), "ms",
                   "wall clock, informational")
        report.row("serving.open_loop.ttft_wall_p99",
                   round(walls[min(int(0.99 * len(walls)),
                                   len(walls) - 1)] * 1e3, 2), "ms",
                   "wall clock, informational")
    report.row("serving.open_loop.plan_time", round(m["plan_time_s"] * 1e3,
                                                    2), "ms",
               "host planning hidden behind device steps (wall)")
    report.row("serving.open_loop.commit_wait", round(m["commit_wait_s"]
                                                      * 1e3, 2), "ms",
               "host blocked on device results (wall)")
    report.row("serving.open_loop.ticks", m["ticks"], "ticks",
               f"{sum(len(s) for s in streams.values())} tokens streamed")
