"""Reproduce the paper's Tables 3/4/5: AHP framework selection run on the
paper's own Apache-Bench measurements (Table 2).

Validation: our AHP implementation must (a) rank the alternatives in the
paper's order for every scenario and (b) match the paper's reported
selection percentages to within 1.5 points (the paper rounds to 0.1%;
residual differences come from its unstated eigenvector iteration count).
"""
from __future__ import annotations

from repro.core.ahp import PAPER_RESULTS, reproduce_paper_tables


def run(report) -> None:
    results = reproduce_paper_tables()
    for scenario, res in results.items():
        paper = PAPER_RESULTS[scenario]
        ours = {a: float(s) for a, s in zip(res.alternatives, res.scores)}
        paper_rank = sorted(paper, key=paper.get, reverse=True)
        our_rank = [a for a, _ in res.ranking()]
        max_dev = max(abs(ours[a] - paper[a]) for a in paper)
        report.table(f"AHP — {scenario}", res.table())
        report.row(f"ahp/{scenario}/rank_match",
                   value=int(our_rank == paper_rank), unit="bool",
                   derived=f"ours={our_rank} paper={paper_rank}")
        report.row(f"ahp/{scenario}/max_abs_dev_pct",
                   value=100 * max_dev, unit="pct",
                   derived=" ".join(f"{a}:{ours[a]*100:.1f}/{paper[a]*100:.1f}"
                                    for a in paper))
        report.check(f"ahp/{scenario}", our_rank == paper_rank
                     and max_dev < 0.015,
                     f"rank {our_rank} vs {paper_rank}, dev {max_dev:.4f}")
        cr = max(v for v in res.consistency.values())
        report.row(f"ahp/{scenario}/max_consistency_ratio", value=cr,
                   unit="CR", derived="Saaty CR<0.1 acceptable")
